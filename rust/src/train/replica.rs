//! Multi-replica data-parallel training with bandwidth-lean buffer-level
//! parameter averaging — the throughput multiplier on top of the
//! device-resident engine.
//!
//! One [`Engine`] saturates one PJRT device. This module runs **N engine
//! replicas**, each on its own worker thread with its own PJRT client and
//! its own [`crate::train::ResidentState`] (parameters *and* momenta
//! uploaded once per replica — exactly the serving-worker isolation
//! pattern), stepping over **disjoint batch shards** of the same epoch
//! ([`crate::data::Shard`]: all replicas shuffle with the epoch seed and
//! deal the full batches round-robin, so shards are disjoint and
//! equal-length by construction):
//!
//! ```text
//!              ┌ replica 0: own PJRT client ── ResidentState ─ shard 0 ┐
//!   dataset ───┼ replica 1: own PJRT client ── ResidentState ─ shard 1 ┼──┐
//!              └ …                                                     ┘  │
//!        every k steps (and at each epoch boundary):                      │
//!   ┌──────────────────────────────────────────────────────────────────┐  │
//!   │ sync plan (freeze::sync_slot_partition): frozen leaves never     │◀─┘
//!   │ move; each replica downloads only the *trainable* leaf buffers,  │
//!   │ encodes them as deltas vs the last broadcast mean (exact XOR     │
//!   │ bit-deltas, or int8-quantized under --sync-compress q8), the     │
//!   │ coordinator folds the frames into a reusable accumulator, means  │
//!   │ in f32, and broadcasts the mean back as one shared delta frame;  │
//!   │ replicas decode it into their baseline and re-upload in place    │
//!   │ (upload_rebind: counted transfers; every wire byte is metered)   │
//!   └──────────────────────────────────────────────────────────────────┘
//! ```
//!
//! **Averaging policy** (the documented decision): parameters average as
//! `mean = (Σ replica values) / N`, summed in replica order in f32 — for
//! N replicas holding identical values the mean is bit-identical to the
//! input (the N=2 case is exact IEEE doubling + halving), which is what
//! lets `integration_train_replicas` pin a 2-replica run on identical
//! shards against the 1-replica trajectory bit-for-bit. The delta wire
//! format preserves that argument because the exact codec is a *bit*
//! delta (XOR), losslessly invertible — see [`super::sync`] for the
//! codec, the `last` baseline lockstep, and why an arithmetic f32 delta
//! would break the pin. Momenta follow [`MomentumPolicy`]:
//! [`MomentumPolicy::Average`] (default) treats the momentum of every
//! trainable slot exactly like the parameter itself, so the post-average
//! SGD state is the mean trajectory's state; [`MomentumPolicy::Reset`]
//! zeroes them instead (the conservative choice when shards are
//! statistically very different — stale per-shard momenta can point away
//! from the averaged iterate). Frozen factors are *never* exchanged:
//! they start identical, are never stepped, and every epoch that thaws
//! them under Algorithm 2 averages them while trainable — the boundary
//! average is therefore mandatory, not an optimization, and it is also
//! what keeps every replica's (and the coordinator's) delta baselines in
//! lockstep across freeze-pattern swaps.
//!
//! Averaging is **host-mediated** by design: each replica owns a separate
//! PJRT client, and buffers of different clients cannot meet in one device
//! computation — an XLA averaging computation (lowered like `metrics_acc`)
//! could only average buffers *within* one client, which is the wrong
//! topology here. The download → delta-encode → f32 mean → decode → upload
//! path costs exactly `2 · |trainable|` transfers per replica per event,
//! every one of them counted
//! ([`crate::train::ResidentParams::upload_rebind`]), and its wire bytes
//! are metered per replica (`lrta_train_barrier_bytes_{exchanged,skipped,
//! full}` under a `{replica}` label) so tests can assert nothing else
//! crossed the boundary — including that frozen leaves contribute zero
//! bytes.
//!
//! **Epoch driver**: replicas honor `TrainConfig::pipelined` like the
//! single-engine trainer — the averaging cadence rides the per-step hook
//! of [`Engine::run_epoch_pipelined_sharded`] (or
//! [`Engine::run_epoch_sharded`] under `--no-pipeline`), so barrier leaf
//! downloads overlap the tail of the last dispatched step instead of
//! forcing the whole run onto the serial loop. Each replica's report says
//! which driver it used.
//!
//! **Freeze-pattern synchronization**: every replica runs the same
//! [`FreezeScheduler`] over the same epoch indices, so Algorithm 2's a↔b
//! swaps happen at the same boundary on every replica, each via the
//! existing [`crate::freeze::train_slot_bindings`] rebinding — zero
//! re-uploads per replica, asserted through the same upload accounting as
//! the single-engine path.
//!
//! The coordinator (the caller's thread) is pure host logic: it collects
//! per-event contribution frames, folds them through the persistent
//! [`MeanState`] accumulator (allocated once, reused every barrier),
//! broadcasts the mean frame, folds per-replica epoch stats into one
//! [`RunRecord`], and re-raises the first replica failure. Replica 0
//! additionally evaluates the (averaged) model each epoch on its resident
//! buffers and reports the run's final parameters.

use crate::checkpoint::Params;
use crate::coordinator::{
    effective_pattern_suffix, load_schedule_executables, zero_momenta, TrainConfig,
};
use crate::data::{DataSource, Dataset, Shard};
use crate::faults::{self, Seam};
use crate::freeze::FreezeScheduler;
use crate::metrics::{EpochRecord, EvictionRecord, RunRecord};
use crate::obs::{Counter, Registry, Tracer};
use crate::runtime::{download_tensor, ArtifactMeta, Manifest, Runtime};
use crate::tensor::Tensor;
use crate::train::sync::{MeanState, ReplicaSyncState, SyncFrame, SyncPlan};
use crate::train::{Engine, MetricsAccumulator, ResidentState, SyncCompress};
use anyhow::{anyhow, bail, Result};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

/// How replica momenta combine at a parameter-averaging event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MomentumPolicy {
    /// Average momenta exactly like parameters (default): the post-event
    /// optimizer state is the mean trajectory's state, and N identical
    /// replicas reproduce one replica bit-for-bit.
    Average,
    /// Zero momenta after every averaging event: discards per-shard
    /// momentum that may point away from the averaged iterate, at the cost
    /// of re-warming the optimizer after each event. Ships zero momentum
    /// bytes in either direction (the zeros are synthesized locally).
    Reset,
}

impl MomentumPolicy {
    /// Parse a CLI spelling (`avg`/`average` or `reset`).
    pub fn parse(s: &str) -> Option<MomentumPolicy> {
        match s {
            "avg" | "average" => Some(MomentumPolicy::Average),
            "reset" => Some(MomentumPolicy::Reset),
            _ => None,
        }
    }
}

/// Configuration of a data-parallel replica run (composes with the usual
/// [`TrainConfig`] for everything schedule/data/variant related).
#[derive(Clone, Copy, Debug)]
pub struct ReplicaConfig {
    /// Number of engine replicas (own PJRT client + resident state each).
    pub replicas: usize,
    /// Average every `k` steps; `0` averages only at epoch boundaries. An
    /// epoch boundary always averages whatever the cadence left un-synced,
    /// so replicas agree on every frozen↔trainable role swap.
    pub avg_every: usize,
    /// What happens to momenta at an averaging event.
    pub momenta: MomentumPolicy,
    /// Wire codec for the barrier's delta exchange
    /// ([`SyncCompress::Exact`] keeps the bit-for-bit parity pin;
    /// `--sync-compress q8` trades it for ~4× smaller frames).
    pub compress: SyncCompress,
    /// Give every replica the *full* batch stream instead of a disjoint
    /// shard. Parity testing only: N identical replicas must reproduce the
    /// single-engine trajectory bit-for-bit.
    pub identical_shards: bool,
    /// Supervise the fleet (default): a replica that dies (panic or
    /// error) or misses the barrier deadline is *evicted* — the run
    /// degrades to the survivors instead of aborting, and the
    /// [`RunRecord`] carries one [`EvictionRecord`] per eviction. Off
    /// (`--no-evict`): any replica death aborts the whole run with that
    /// replica's own message.
    pub evict: bool,
    /// How long the coordinator lets an averaging barrier stay open
    /// before evicting the replicas it is still waiting on. Only
    /// consulted while a barrier is open and `evict` is set.
    pub barrier_timeout: Duration,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        ReplicaConfig {
            replicas: 2,
            avg_every: 0,
            momenta: MomentumPolicy::Average,
            compress: SyncCompress::Exact,
            identical_shards: false,
            evict: true,
            barrier_timeout: Duration::from_secs(30),
        }
    }
}

/// Per-replica transfer accounting, the multi-replica form of the
/// single-engine "zero re-uploads" claim: across a whole run,
/// `param_uploads == initial_param_uploads + avg_slot_uploads` — steps
/// chain buffer-to-buffer and freeze-pattern swaps re-bind, so *only* the
/// documented averaging traffic crosses the host boundary. The byte
/// fields price that traffic: `avg_bytes_full` is the naive
/// every-leaf-raw-f32 reference, `avg_bytes_skipped` what the frozen-leaf
/// skip avoided, `avg_bytes_exchanged` the encoded bytes that actually
/// moved (both directions).
#[derive(Clone, Debug)]
pub struct ReplicaReport {
    /// Replica index (`0..replicas`).
    pub replica: usize,
    /// Parameter/momentum uploads at engine construction (the one full
    /// state upload).
    pub initial_param_uploads: usize,
    /// Final value of the engine's parameter-upload counter.
    pub param_uploads: usize,
    /// Averaging barriers this replica participated in.
    pub avg_events: usize,
    /// Counted uploads attributable to averaging (`Σ` over events of
    /// params + momenta re-uploaded).
    pub avg_slot_uploads: usize,
    /// Encoded barrier bytes this replica actually exchanged, both
    /// directions (contribution frames sent + broadcast frames received).
    pub avg_bytes_exchanged: u64,
    /// Bytes the frozen-leaf skip kept off the wire (raw-f32 priced,
    /// both directions, summed over events).
    pub avg_bytes_skipped: u64,
    /// Bytes a naive full-universe raw-f32 exchange would have moved
    /// (all param leaves — frozen included — plus averaged momenta).
    pub avg_bytes_full: u64,
    /// Which epoch driver stepped this replica (`TrainConfig::pipelined`).
    pub pipelined: bool,
    /// Demux fallbacks on this replica's runtime (0 = fully
    /// buffer-chained).
    pub demux_fallbacks: usize,
    /// Training batches this replica stepped through.
    pub batches: usize,
}

impl ReplicaReport {
    /// Parameter uploads *not* accounted for by the initial upload or the
    /// averaging budget — must be 0 (steps and pattern swaps never
    /// re-upload).
    pub fn unaccounted_uploads(&self) -> usize {
        self.param_uploads - self.initial_param_uploads - self.avg_slot_uploads
    }

    /// Bytes the delta/quantize encoding saved on top of the frozen-leaf
    /// skip. Non-negative by construction: every codec falls back to raw
    /// f32 per leaf whenever encoding would not win.
    pub fn avg_bytes_saved_by_delta(&self) -> u64 {
        (self.avg_bytes_full - self.avg_bytes_skipped).saturating_sub(self.avg_bytes_exchanged)
    }

    /// Human label of the epoch driver this replica ran.
    pub fn driver(&self) -> &'static str {
        if self.pipelined {
            "pipelined"
        } else {
            "serial"
        }
    }
}

/// Result of a data-parallel run: the combined record (loss/accuracy
/// weighted across shards, eval from the averaged model) plus the final
/// averaged state and per-replica transfer accounting.
pub struct ReplicaRun {
    /// Combined per-epoch record; `test_acc` is replica 0's evaluation of
    /// the post-boundary-average (i.e. global) model.
    pub record: RunRecord,
    /// Final parameters (replica 0's state after the last boundary
    /// average — identical on every replica at that point).
    pub params: Params,
    /// Final momenta (same provenance as `params`).
    pub momenta: Params,
    /// One transfer-accounting report per replica.
    pub reports: Vec<ReplicaReport>,
}

/// Everything a replica reports back on completion.
struct ReplicaOutcome {
    report: ReplicaReport,
    /// Final state — populated by replica 0 only (identical everywhere
    /// after the final boundary average; shipping N copies is waste).
    state: Option<(Params, Params)>,
}

/// Replica → coordinator protocol.
enum ToCoord {
    /// Contribution to averaging barrier `event` (a global ordinal; every
    /// replica must be at the same one — anything else is a desync bug).
    /// The frame holds delta-encoded trainable leaves per the sync plan.
    Avg { replica: usize, event: u64, frame: SyncFrame },
    /// One epoch's local stats (sums, so the coordinator can weight them).
    Epoch {
        replica: usize,
        epoch: usize,
        loss_sum: f32,
        correct_sum: f32,
        samples: usize,
        batches: usize,
        median_step_secs: f64,
    },
    /// Replica 0's evaluation of the averaged model after `epoch`.
    Eval { epoch: usize, acc: f64 },
    /// Per-step liveness beacon (sent only under supervision): the last
    /// one received is the eviction record's "how far did it get".
    Heartbeat { replica: usize, epoch: usize, step: usize },
    /// Clean completion.
    Done { replica: usize, outcome: Box<ReplicaOutcome> },
    /// The replica thread panicked; sent from its `catch_unwind` so the
    /// fleet can never deadlock on a contribution that will not arrive.
    Died { replica: usize, message: String },
    /// The replica's run returned an error.
    Failed { replica: usize, message: String },
}

/// Everything one replica thread needs to run (owned clones / shared
/// `Arc`s — the thread outlives the caller's borrows).
struct ReplicaJob {
    idx: usize,
    manifest: Manifest,
    cfg: TrainConfig,
    rcfg: ReplicaConfig,
    params: Params,
    momenta: Params,
    /// Shared read-only corpus — generated (or opened from storage) once
    /// by the coordinator, not once per replica. Streamed sources share
    /// one provider, so the replicas' disjoint shards also share its
    /// chunk cache.
    train_source: DataSource,
    test_data: Arc<Dataset>,
    to_coord: mpsc::Sender<ToCoord>,
    from_coord: mpsc::Receiver<Arc<SyncFrame>>,
    /// Span recorder shared with the coordinator — each replica thread
    /// records into its own lane of the same ring.
    tracer: Tracer,
    /// Metrics registry (`--metrics-out`): each replica registers its
    /// barrier byte counters and runtime transfer counters under a
    /// `{replica}` label.
    registry: Option<Registry>,
}

/// Run `cfg.epochs` of data-parallel training across `rcfg.replicas`
/// engine replicas. `params` must already match the variant (decompose
/// first, as with [`crate::coordinator::Trainer`]); momenta start at zero
/// on every replica.
///
/// Replicas honor `cfg.pipelined` (the same flag single-engine runs use):
/// the averaging barrier composes with the overlapped driver through the
/// per-step hook of [`Engine::run_epoch_pipelined_sharded`]. `cfg.resident`
/// is ignored — replicas always step the resident engine (the literal
/// baseline has no buffers to average).
pub fn run_replicas(
    manifest: &Manifest,
    cfg: &TrainConfig,
    rcfg: &ReplicaConfig,
    params: &Params,
) -> Result<ReplicaRun> {
    run_replicas_traced(manifest, cfg, rcfg, params, Tracer::default(), None)
}

/// [`run_replicas`] with observability wired in: every replica records its
/// `average_barrier` spans — split into `barrier_download` /
/// `barrier_wait` / `barrier_upload` children — into `tracer`, one lane
/// per replica thread, and registers its barrier byte counters (and its
/// runtime's transfer counters) in `registry` under a `{replica}` label.
/// The multi-replica half of `lrta train --trace-out / --metrics-out`.
pub fn run_replicas_traced(
    manifest: &Manifest,
    cfg: &TrainConfig,
    rcfg: &ReplicaConfig,
    params: &Params,
    tracer: Tracer,
    registry: Option<Registry>,
) -> Result<ReplicaRun> {
    run_replicas_sourced(manifest, cfg, rcfg, params, tracer, registry, None)
}

/// [`run_replicas_traced`] with an explicit training [`DataSource`]:
/// `None` keeps the classic behavior (synthesize `cfg.train_size` samples
/// in memory), `Some` lets the fleet stream its shards from a published
/// object-store corpus (`lrta train --replicas N --data-store URI`) —
/// batches are bit-identical either way, so the source never changes the
/// averaged trajectory.
#[allow(clippy::too_many_arguments)]
pub fn run_replicas_sourced(
    manifest: &Manifest,
    cfg: &TrainConfig,
    rcfg: &ReplicaConfig,
    params: &Params,
    tracer: Tracer,
    registry: Option<Registry>,
    source: Option<DataSource>,
) -> Result<ReplicaRun> {
    if rcfg.replicas == 0 {
        bail!("replica count must be positive");
    }
    // the synthetic corpus is deterministic in the seed and read-only —
    // generate (or accept) it once and share it across every replica thread
    let train_source = match source {
        Some(s) => s,
        None => DataSource::memory(Arc::new(Dataset::synthetic(cfg.train_size, cfg.seed))),
    };
    // every shard must receive at least one batch per epoch — otherwise
    // the run would "succeed" with zero training and report the initial
    // parameters' accuracy as if it had fine-tuned
    if cfg.epochs > 0 {
        let scheduler = FreezeScheduler::new(cfg.freeze);
        let suffix0 = effective_pattern_suffix(&cfg.variant, scheduler.pattern(0));
        let name = Manifest::name_of(&cfg.model, &cfg.variant, "train", suffix0);
        let batch = manifest.artifact(&name)?.batch.max(1);
        let total_batches = train_source.len() / batch;
        let shard_view = if rcfg.identical_shards {
            Shard::full()
        } else {
            Shard::of(0, rcfg.replicas)
        };
        if shard_view.num_batches(total_batches) == 0 {
            bail!(
                "{} full batches of {batch} cannot feed {} replicas — every shard would \
                 be empty; lower --replicas or raise the training-set size",
                total_batches,
                rcfg.replicas
            );
        }
    }
    let momenta = zero_momenta(params);
    let test_data = Arc::new(Dataset::synthetic(cfg.test_size, cfg.seed ^ 0xDEAD_BEEF));
    let (to_coord, from_replicas) = mpsc::channel::<ToCoord>();
    let mut reply_txs = Vec::with_capacity(rcfg.replicas);
    let mut joins = Vec::with_capacity(rcfg.replicas);
    for idx in 0..rcfg.replicas {
        let (reply_tx, reply_rx) = mpsc::channel::<Arc<SyncFrame>>();
        reply_txs.push(reply_tx);
        let job = ReplicaJob {
            idx,
            manifest: manifest.clone(),
            cfg: cfg.clone(),
            rcfg: *rcfg,
            params: params.clone(),
            momenta: momenta.clone(),
            train_source: train_source.clone(),
            test_data: Arc::clone(&test_data),
            to_coord: to_coord.clone(),
            from_coord: reply_rx,
            tracer: tracer.clone(),
            registry: registry.clone(),
        };
        joins.push(
            thread::Builder::new()
                .name(format!("lrta-replica-{idx}"))
                .spawn(move || replica_main(job))
                .expect("spawn replica thread"),
        );
    }
    drop(to_coord); // coordinator's recv ends when every replica exits

    // the coordinator owns the reply senders: evicting a replica drops
    // exactly its sender (so a live straggler errors out of its barrier
    // recv instead of blocking forever), and returning from `coordinate`
    // — success or failure — drops the rest so every join terminates
    let reply_txs: Vec<Option<mpsc::Sender<Arc<SyncFrame>>>> =
        reply_txs.into_iter().map(Some).collect();
    let result =
        coordinate(cfg, rcfg, params, &momenta, from_replicas, reply_txs, registry.as_ref());
    let mut panics = Vec::new();
    for (idx, join) in joins.into_iter().enumerate() {
        if join.join().is_err() {
            panics.push(idx);
        }
    }
    let run = result?;
    // an evicted replica is allowed to have died unwinding; any other
    // panic means the run's accounting cannot be trusted
    if let Some(&idx) =
        panics.iter().find(|&&i| !run.record.evictions.iter().any(|ev| ev.replica == i))
    {
        bail!("replica {idx} thread panicked (run aborted)");
    }
    Ok(run)
}

/// The coordinator's supervision state: who is still live, who was
/// evicted and why, and the reply senders whose drop doubles as the
/// eviction signal to a still-running straggler.
struct Supervisor {
    evicted: Vec<bool>,
    evictions: Vec<EvictionRecord>,
    reply_txs: Vec<Option<mpsc::Sender<Arc<SyncFrame>>>>,
    /// Last heartbeat per replica: `(epoch, step-within-epoch)`.
    last_seen: Vec<(usize, usize)>,
    counter: Counter,
    verbose: bool,
}

impl Supervisor {
    fn live(&self) -> usize {
        self.evicted.iter().filter(|e| !**e).count()
    }

    /// Evict `r`: drop its reply sender, record the accounting. The
    /// caller re-checks the open barrier afterwards — losing a member is
    /// exactly what lets a barrier close over the survivors.
    fn evict(&mut self, r: usize, event: u64, reason: String) {
        self.evicted[r] = true;
        self.reply_txs[r] = None;
        let (last_epoch, last_step) = self.last_seen[r];
        let survivors = self.live();
        if self.verbose {
            eprintln!("[coordinator] evicting replica {r} ({reason}); {survivors} survive");
        }
        self.counter.inc();
        self.evictions.push(EvictionRecord {
            replica: r,
            event,
            last_epoch,
            last_step,
            reason,
            survivors,
        });
    }
}

/// The coordinator loop: collect averaging contributions, broadcast means,
/// fold epoch stats, and assemble the combined record once every replica
/// reported completion. `params`/`momenta` seed the delta baselines —
/// the same initial state every replica uploads, so both sides of the
/// channel decode against identical references from the first barrier on.
///
/// Under supervision (`rcfg.evict`, the default) the loop also plays
/// fleet supervisor: a replica that reports death ([`ToCoord::Died`] /
/// [`ToCoord::Failed`]) or misses an open barrier's deadline is evicted,
/// the barrier re-examined and — if every *remaining* member has
/// contributed — closed over the survivors only. [`MeanState::average`]
/// divides by the number of frames it is handed, so the survivor-only
/// mean needs no rescaling beyond passing fewer frames; the broadcast's
/// [`SyncFrame::membership`] bump is how replicas observe the change.
/// The liveness deadline is armed only while a barrier is open: that is
/// the one place a dead peer stalls the *fleet* rather than just itself.
fn coordinate(
    cfg: &TrainConfig,
    rcfg: &ReplicaConfig,
    params: &Params,
    momenta: &Params,
    rx: mpsc::Receiver<ToCoord>,
    reply_txs: Vec<Option<mpsc::Sender<Arc<SyncFrame>>>>,
    registry: Option<&Registry>,
) -> Result<ReplicaRun> {
    let n = rcfg.replicas;
    let mut sup = Supervisor {
        evicted: vec![false; n],
        evictions: Vec::new(),
        reply_txs,
        last_seen: vec![(0, 0); n],
        counter: Counter::new(),
        verbose: cfg.verbose,
    };
    if let Some(reg) = registry {
        reg.register_counter("train", "evictions", &[], &sup.counter)?;
    }

    /// One shard's epoch stats: `(loss_sum, correct_sum, samples, batches,
    /// median_step_secs)`.
    type ShardStats = (f32, f32, usize, usize, f64);

    #[derive(Clone)]
    struct EpochAcc {
        /// Per-replica stats, folded in replica-index order at assembly —
        /// f32 sums are order-sensitive, and message-arrival order is not
        /// deterministic across threads.
        shards: Vec<Option<ShardStats>>,
        test_acc: f64,
    }
    let blank = EpochAcc { shards: vec![None; n], test_acc: f64::NAN };
    let mut epochs = vec![blank; cfg.epochs];
    // persistent fold state: `last` baselines plus the reusable mean
    // accumulator (allocated at the first barrier, reused ever after)
    let mut mean_state = MeanState::new(params, momenta, rcfg.compress);
    let mut pending: Vec<Option<SyncFrame>> = (0..n).map(|_| None).collect();
    let mut pending_event: Option<u64> = None;
    let mut barrier_deadline: Option<Instant> = None;
    let mut outcomes: Vec<Option<ReplicaOutcome>> = (0..n).map(|_| None).collect();
    let mut done = 0usize;

    while done < sup.live() {
        // arm the liveness deadline only while a barrier is open — that is
        // the one state where a dead peer blocks the whole fleet
        let msg = match barrier_deadline {
            Some(deadline) => {
                match rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
                    Ok(m) => Some(m),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        bail!("all replica threads exited before reporting completion")
                    }
                }
            }
            None => Some(rx.recv().map_err(|_| {
                anyhow!("all replica threads exited before reporting completion")
            })?),
        };
        match msg {
            None => {
                // barrier deadline expired: every member the open barrier
                // is still waiting on is diagnosed as a straggler
                let event = pending_event.unwrap_or(0);
                let ms = rcfg.barrier_timeout.as_millis();
                for r in 0..n {
                    if !sup.evicted[r] && pending[r].is_none() && outcomes[r].is_none() {
                        sup.evict(
                            r,
                            event,
                            format!(
                                "missed the averaging-barrier deadline ({ms}ms) at event {event}"
                            ),
                        );
                    }
                }
                if sup.live() == 0 {
                    bail!("every replica was evicted; no survivors to finish the run");
                }
            }
            Some(ToCoord::Heartbeat { replica, epoch, step }) => {
                if !sup.evicted[replica] {
                    sup.last_seen[replica] = (epoch, step);
                }
                continue;
            }
            Some(ToCoord::Avg { replica, event, frame }) => {
                if sup.evicted[replica] {
                    continue; // stale contribution from a zombie straggler
                }
                match pending_event {
                    None => {
                        pending_event = Some(event);
                        if rcfg.evict {
                            barrier_deadline = Some(Instant::now() + rcfg.barrier_timeout);
                        }
                    }
                    Some(e) if e == event => {}
                    Some(e) => bail!(
                        "replica desync: replica {replica} at averaging event {event}, \
                         barrier open at {e}"
                    ),
                }
                if pending[replica].replace(frame).is_some() {
                    bail!("replica {replica} contributed twice to averaging event {event}");
                }
            }
            Some(ToCoord::Epoch {
                replica,
                epoch,
                loss_sum,
                correct_sum,
                samples,
                batches,
                median_step_secs,
            }) => {
                if sup.evicted[replica] {
                    continue;
                }
                let acc = epochs
                    .get_mut(epoch)
                    .ok_or_else(|| anyhow!("replica {replica} reported epoch {epoch}"))?;
                let stats = (loss_sum, correct_sum, samples, batches, median_step_secs);
                if acc.shards[replica].replace(stats).is_some() {
                    bail!("replica {replica} reported epoch {epoch} twice");
                }
                continue;
            }
            Some(ToCoord::Eval { epoch, acc }) => {
                epochs
                    .get_mut(epoch)
                    .ok_or_else(|| anyhow!("eval reported for epoch {epoch}"))?
                    .test_acc = acc;
                continue;
            }
            Some(ToCoord::Done { replica, outcome }) => {
                if !sup.evicted[replica] {
                    outcomes[replica] = Some(*outcome);
                    done += 1;
                }
                continue;
            }
            Some(ToCoord::Died { replica, message })
            | Some(ToCoord::Failed { replica, message }) => {
                if sup.evicted[replica] {
                    continue; // already diagnosed (e.g. deadline beat the report)
                }
                if !rcfg.evict {
                    bail!("replica {replica} failed: {message}");
                }
                sup.evict(replica, pending_event.unwrap_or(0), message);
                if sup.live() == 0 {
                    bail!("every replica was evicted; no survivors to finish the run");
                }
            }
        }
        // an eviction (or a fresh contribution) may be what completes the
        // open barrier: close it once every *remaining* member contributed.
        // A frame from a member evicted after contributing stays in — the
        // eviction excuses absence, it does not retract a contribution.
        if pending_event.is_some()
            && (0..n).all(|r| sup.evicted[r] || outcomes[r].is_some() || pending[r].is_some())
        {
            let contributions: Vec<SyncFrame> =
                pending.iter_mut().filter_map(|p| p.take()).collect();
            // fold in replica-index order into the persistent accumulator;
            // `average` divides by the frame count, so a survivor-only
            // barrier rescales the mean by construction. One shared
            // broadcast frame per barrier (receivers only decode it, so an
            // Arc avoids N deep clones on the coordinator thread).
            let mut mean = mean_state.average(&contributions)?;
            mean.membership = sup.evictions.len() as u64;
            let mean = Arc::new(mean);
            for (r, tx) in sup.reply_txs.iter().enumerate() {
                let Some(tx) = tx else { continue };
                // under supervision a send failure means the replica died
                // between contributing and receiving; its death report is
                // already in the channel and handles the eviction
                if tx.send(Arc::clone(&mean)).is_err() && !rcfg.evict {
                    bail!("replica {r} exited mid-averaging-barrier");
                }
            }
            pending_event = None;
            barrier_deadline = None;
        }
    }

    // assemble the combined record
    let scheduler = FreezeScheduler::new(cfg.freeze);
    let mut record =
        RunRecord::new(format!("{}_{}_{:?}_r{}", cfg.model, cfg.variant, cfg.freeze, n));
    for (e, acc) in epochs.iter().enumerate() {
        // fold the shards in replica-index order: deterministic f32 sums
        // regardless of which thread reached the channel first
        let mut loss_sum = 0.0f32;
        let mut correct_sum = 0.0f32;
        let mut samples = 0usize;
        let mut batches = 0usize;
        let mut max_median_step = 0.0f64;
        for (r, shard) in acc.shards.iter().enumerate() {
            match *shard {
                Some((l, c, s, b, m)) => {
                    loss_sum += l;
                    correct_sum += c;
                    samples += s;
                    batches += b;
                    // wall-clock is set by the slowest replica
                    max_median_step = max_median_step.max(m);
                }
                // an evicted replica's missing epochs fold survivor
                // shards only — the degraded rows are still exact over
                // the batches that actually ran
                None if sup.evicted[r] => {}
                None => bail!("epoch {e}: replica {r} never reported its stats"),
            }
        }
        let rec = EpochRecord {
            epoch: e,
            // weighted means over all shards: scaling numerator and
            // denominator by the replica count keeps the identical-shard
            // case bit-identical to the single-engine division
            loss: loss_sum as f64 / batches.max(1) as f64,
            train_acc: correct_sum as f64 / samples.max(1) as f64,
            test_acc: acc.test_acc,
            step_secs: max_median_step,
            freeze_pattern: effective_pattern_suffix(&cfg.variant, scheduler.pattern(e))
                .to_string(),
        };
        if cfg.verbose {
            println!(
                "[{}] epoch {:>3} pattern={} loss={:.4} train_acc={:.3} test_acc={:.3} \
                 step={:.1}ms ({} replicas)",
                record.name,
                e,
                rec.freeze_pattern,
                rec.loss,
                rec.train_acc,
                rec.test_acc,
                rec.step_secs * 1e3,
                sup.live()
            );
        }
        record.epochs.push(rec);
    }
    let mut reports: Vec<ReplicaReport> = Vec::with_capacity(n);
    let mut state = None;
    for (r, outcome) in outcomes.into_iter().enumerate() {
        match outcome {
            Some(outcome) => {
                if let Some(s) = outcome.state {
                    state = Some(s);
                }
                reports.push(outcome.report);
            }
            None if sup.evicted[r] => {}
            None => bail!("replica {r} neither completed nor was evicted"),
        }
    }
    let (params, momenta) = match state {
        Some(s) => s,
        // replica 0 (the state reporter) was evicted: the coordinator's
        // own fold state after the last closed barrier IS the survivors'
        // resident state bit-for-bit — frozen leaves never move, and
        // Reset-policy momenta are zeros on both sides (see
        // [`MeanState::final_state`])
        None if sup.evicted[0] => mean_state.final_state(),
        None => bail!("replica 0 reported no final state"),
    };
    record.evictions = sup.evictions;
    Ok(ReplicaRun { record, params, momenta, reports })
}

/// Thread entry: run the replica and report the outcome, whatever it is.
///
/// A *panic* must reach the coordinator just like an `Err` does —
/// otherwise the surviving replicas block forever inside the averaging
/// barrier while the coordinator waits for a contribution that will never
/// arrive. So the run is wrapped in `catch_unwind` and the payload turned
/// into a [`ToCoord::Died`] before the thread exits (the replica-side
/// analogue of the [`crate::train::Prefetcher`] panic re-raise) — the
/// coordinator then aborts with the payload (`--no-evict`) or evicts this
/// replica and finishes on the survivors, both in bounded time.
fn replica_main(job: ReplicaJob) {
    let idx = job.idx;
    let to_coord = job.to_coord.clone();
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_replica(job)));
    let report = match result {
        Ok(Ok(outcome)) => {
            let _ = to_coord.send(ToCoord::Done { replica: idx, outcome: Box::new(outcome) });
            return;
        }
        Ok(Err(e)) => ToCoord::Failed { replica: idx, message: format!("{e:#}") },
        Err(payload) => {
            let message = payload
                .downcast_ref::<&str>()
                .map(|s| format!("panic: {s}"))
                .or_else(|| payload.downcast_ref::<String>().map(|s| format!("panic: {s}")))
                .unwrap_or_else(|| "replica thread panicked".into());
            ToCoord::Died { replica: idx, message }
        }
    };
    let _ = to_coord.send(report);
}

/// One replica's whole run: own runtime, own executables, own resident
/// state; barriers with the coordinator at every averaging event.
fn run_replica(job: ReplicaJob) -> Result<ReplicaOutcome> {
    let ReplicaJob {
        idx,
        manifest,
        cfg,
        rcfg,
        params,
        momenta,
        train_source,
        test_data,
        to_coord,
        from_coord,
        tracer,
        registry,
    } = job;
    let rt = Runtime::cpu()?;
    let scheduler = FreezeScheduler::new(cfg.freeze);

    // barrier byte meters — registered under this replica's label so the
    // Prometheus exposition carries per-replica wire accounting
    let bytes_exchanged = Counter::new();
    let bytes_skipped = Counter::new();
    let bytes_full = Counter::new();
    if let Some(reg) = &registry {
        let label = idx.to_string();
        let labels: [(&str, &str); 1] = [("replica", &label)];
        reg.register_counter("train", "barrier_bytes_exchanged", &labels, &bytes_exchanged)?;
        reg.register_counter("train", "barrier_bytes_skipped", &labels, &bytes_skipped)?;
        reg.register_counter("train", "barrier_bytes_full", &labels, &bytes_full)?;
        rt.register_metrics(reg, &labels)?;
    }

    // one executable per scheduled pattern, compiled on this replica's own
    // client — the same schedule resolution the single-engine trainer uses
    let train_exes = load_schedule_executables(&rt, &manifest, &cfg)?;
    // replica 0 doubles as the evaluator of the averaged model
    let infer = if idx == 0 {
        let name = Manifest::name_of(&cfg.model, &cfg.variant, "infer", "none");
        let meta = manifest.artifact(&name)?.clone();
        let exe = rt.load_hlo(manifest.hlo_path(&meta))?;
        Some((exe, meta))
    } else {
        None
    };

    let shard = if rcfg.identical_shards {
        Shard::full()
    } else {
        Shard::of(idx, rcfg.replicas)
    };

    let mut engine = Engine::upload(&rt, &params, &momenta)?;
    engine.set_tracer(tracer.clone());
    engine.set_fault_scope(format!("replica{idx}"));
    if cfg.pipelined {
        // the overlapped driver folds loss/correct on device; use the
        // manifest-lowered accumulator like the single-engine trainer
        engine.attach_metrics(MetricsAccumulator::create(&rt, Some(&manifest))?);
    }
    if cfg.verbose {
        let driver = if cfg.pipelined { "pipelined" } else { "serial" };
        println!("[replica {idx}] epoch driver: {driver}");
    }
    let initial_param_uploads = engine.param_uploads();
    let mut barrier = AvgBarrier {
        replica: idx,
        scope: format!("replica{idx}"),
        policy: rcfg.momenta,
        events: 0,
        membership: 0,
        slot_uploads: 0,
        sync: ReplicaSyncState::new(&params, &momenta, rcfg.compress),
        bytes_exchanged,
        bytes_skipped,
        bytes_full,
        to_coord: &to_coord,
        from_coord: &from_coord,
        tracer: &tracer,
    };
    let mut total_batches = 0usize;

    for epoch in 0..cfg.epochs {
        let lr = cfg.lr.lr_at(epoch);
        let suffix = effective_pattern_suffix(&cfg.variant, scheduler.pattern(epoch));
        let (exe, meta) = train_exes
            .get(suffix)
            .ok_or_else(|| anyhow!("no train executable for pattern '{suffix}'"))?;
        // epoch boundary: Algorithm 2 may swap a↔b — re-bind the resident
        // buffers to the new slot layout (pure permutation, zero uploads);
        // synchronized across replicas because every replica runs the same
        // scheduler over the same epoch index
        engine.state().rebind_for(meta)?;
        // what this epoch's barriers exchange and skip, priced in bytes —
        // recomputed per epoch because a↔b swaps change the partition
        let plan = SyncPlan::of(meta, rcfg.momenta == MomentumPolicy::Average);

        // the shared epoch loop over this replica's shard — pipelined or
        // serial per cfg.pipelined, averaging cadence riding the per-step
        // hook either way (the step meter times the local step — barrier
        // waits show up in wall-clock, not step latency, because averaging
        // happens outside the timed step)
        let epoch_seed = cfg.seed ^ epoch as u64;
        let mut since_avg = 0usize;
        let mut step_in_epoch = 0usize;
        let mut hook = |rt: &Runtime, state: &mut ResidentState| {
            step_in_epoch += 1;
            if rcfg.evict {
                // liveness beacon: best-effort (a closed channel means the
                // coordinator already gave up; the driver surfaces that)
                let _ = to_coord.send(ToCoord::Heartbeat {
                    replica: idx,
                    epoch,
                    step: step_in_epoch,
                });
            }
            since_avg += 1;
            if rcfg.avg_every > 0 && since_avg == rcfg.avg_every {
                barrier.average(rt, state, meta, &plan)?;
                since_avg = 0;
            }
            Ok(())
        };
        let stats = if cfg.pipelined {
            engine.run_epoch_pipelined_sharded(
                exe,
                meta,
                &train_source,
                epoch_seed,
                lr,
                shard,
                &mut hook,
            )?
        } else {
            engine.run_epoch_sharded(
                exe,
                meta,
                &train_source,
                epoch_seed,
                lr,
                shard,
                &mut hook,
            )?
        };
        // mandatory boundary average (unless the cadence just did it):
        // after this, frozen↔trainable role swaps are safe because every
        // replica agrees on the whole parameter universe — and the delta
        // baselines stay valid for leaves that freeze next epoch (a frozen
        // leaf's resident value *is* its last broadcast value)
        if since_avg > 0 {
            barrier.average(&rt, engine.state_mut(), meta, &plan)?;
        }
        total_batches += stats.batches;
        to_coord
            .send(ToCoord::Epoch {
                replica: idx,
                epoch,
                loss_sum: stats.loss_sum,
                correct_sum: stats.correct_sum,
                samples: stats.samples,
                batches: stats.batches,
                median_step_secs: stats.meter.median_step(),
            })
            .map_err(|_| anyhow!("coordinator exited"))?;
        if let Some((infer_exe, infer_meta)) = &infer {
            let acc = engine.evaluate(infer_exe, infer_meta, &test_data)?;
            to_coord
                .send(ToCoord::Eval { epoch, acc })
                .map_err(|_| anyhow!("coordinator exited"))?;
        }
    }

    let report = ReplicaReport {
        replica: idx,
        initial_param_uploads,
        param_uploads: engine.param_uploads(),
        avg_events: barrier.events,
        avg_slot_uploads: barrier.slot_uploads,
        avg_bytes_exchanged: barrier.bytes_exchanged.get(),
        avg_bytes_skipped: barrier.bytes_skipped.get(),
        avg_bytes_full: barrier.bytes_full.get(),
        pipelined: cfg.pipelined,
        demux_fallbacks: rt.demux_fallbacks(),
        batches: total_batches,
    };
    let state = if idx == 0 { Some(engine.sync()?) } else { None };
    Ok(ReplicaOutcome { report, state })
}

/// The replica side of one averaging barrier, plus its accounting.
struct AvgBarrier<'a> {
    replica: usize,
    /// Fault-injection scope (`replica{idx}`) for the barrier seams.
    scope: String,
    policy: MomentumPolicy,
    /// Barriers participated in so far (doubles as the global event tag).
    events: usize,
    /// Last membership epoch observed in a broadcast — monotonically
    /// non-decreasing; each bump is one fleet eviction this replica
    /// survived.
    membership: u64,
    /// Counted uploads performed by averaging (params + momenta).
    slot_uploads: usize,
    /// Delta baselines (`last` broadcast mean per leaf) — mutated only by
    /// decoding broadcast frames, in lockstep with the coordinator.
    sync: ReplicaSyncState,
    /// Encoded wire bytes actually exchanged (send + receive).
    bytes_exchanged: Counter,
    /// Raw-f32 bytes the frozen-leaf skip avoided.
    bytes_skipped: Counter,
    /// Raw-f32 bytes of the naive full-universe exchange (reference).
    bytes_full: Counter,
    to_coord: &'a mpsc::Sender<ToCoord>,
    from_coord: &'a mpsc::Receiver<Arc<SyncFrame>>,
    tracer: &'a Tracer,
}

impl AvgBarrier<'_> {
    /// One barrier: download the sync plan's exchanged leaves, contribute
    /// their deltas, block for the mean frame, decode it into the baseline
    /// and re-upload in place. Runs inside the epoch driver's per-step
    /// hook (and once more at the epoch boundary), so it sees the state
    /// between steps; under the pipelined driver the leaf downloads here
    /// are what overlaps the tail of the last dispatched step.
    fn average(
        &mut self,
        rt: &Runtime,
        state: &mut ResidentState,
        meta: &ArtifactMeta,
        plan: &SyncPlan,
    ) -> Result<()> {
        let span = self.tracer.start();
        self.events += 1;

        // download + delta-encode the exchanged leaves (frozen leaves are
        // not in the plan: zero downloads, zero bytes)
        let d_t0 = self.tracer.start();
        let mut leaf_params: Vec<(String, Tensor)> = Vec::with_capacity(plan.exchanged.len());
        let mut leaf_momenta: Vec<(String, Tensor)> = Vec::new();
        for (name, _) in &plan.exchanged {
            let buf = state
                .params
                .get(name)
                .ok_or_else(|| anyhow!("no resident buffer for '{name}'"))?;
            leaf_params.push((name.clone(), download_tensor(buf)?));
            if self.policy == MomentumPolicy::Average {
                let mbuf = state
                    .momenta
                    .get(name)
                    .ok_or_else(|| anyhow!("no resident momentum for '{name}'"))?;
                leaf_momenta.push((name.clone(), download_tensor(mbuf)?));
            }
        }
        let frame = self.sync.encode_contribution(&leaf_params, &leaf_momenta)?;
        self.tracer.end(d_t0, "train", "barrier_download");
        let sent_bytes = frame.wire_bytes();

        faults::hit(Seam::BarrierSend, &self.scope)?;
        self.to_coord
            .send(ToCoord::Avg { replica: self.replica, event: self.events as u64, frame })
            .map_err(|_| anyhow!("coordinator exited during averaging"))?;
        let w_t0 = self.tracer.start();
        faults::hit(Seam::BarrierRecv, &self.scope)?;
        let mean = self.from_coord.recv().map_err(|_| {
            anyhow!(
                "averaging barrier closed by the coordinator \
                 (run aborted or this replica was evicted)"
            )
        })?;
        self.tracer.end(w_t0, "train", "barrier_wait");
        if mean.membership < self.membership {
            bail!(
                "membership epoch went backwards: {} after {}",
                mean.membership,
                self.membership
            );
        }
        self.membership = mean.membership;

        // decode into the baseline (it then *is* the next barrier's
        // reference) and re-upload the mean into the resident buffers
        let u_t0 = self.tracer.start();
        self.sync.apply_broadcast(&mean)?;
        for (name, _) in &mean.params {
            let t = self.sync.last_param(name).ok_or_else(|| anyhow!("no baseline for '{name}'"))?;
            state.params.upload_rebind(rt, name, t)?;
            self.slot_uploads += 1;
        }
        match self.policy {
            MomentumPolicy::Average => {
                for (name, _) in &mean.momenta {
                    let t = self
                        .sync
                        .last_momentum(name)
                        .ok_or_else(|| anyhow!("no momentum baseline for '{name}'"))?;
                    state.momenta.upload_rebind(rt, name, t)?;
                    self.slot_uploads += 1;
                }
            }
            MomentumPolicy::Reset => {
                // synthesized locally: zero wire bytes in either direction
                for slot in &meta.trainable {
                    let zero = Tensor::zeros(&slot.shape);
                    state.momenta.upload_rebind(rt, &slot.name, &zero)?;
                    self.slot_uploads += 1;
                }
            }
        }
        self.tracer.end(u_t0, "train", "barrier_upload");

        self.bytes_exchanged.add(sent_bytes + mean.wire_bytes());
        self.bytes_skipped.add(plan.skipped_bytes());
        self.bytes_full.add(plan.full_bytes());
        self.tracer.end(span, "train", "average_barrier");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn momentum_policy_parses() {
        assert_eq!(MomentumPolicy::parse("avg"), Some(MomentumPolicy::Average));
        assert_eq!(MomentumPolicy::parse("average"), Some(MomentumPolicy::Average));
        assert_eq!(MomentumPolicy::parse("reset"), Some(MomentumPolicy::Reset));
        assert_eq!(MomentumPolicy::parse("x"), None);
    }

    #[test]
    fn report_accounting_is_exact() {
        let report = ReplicaReport {
            replica: 0,
            initial_param_uploads: 10,
            param_uploads: 26,
            avg_events: 2,
            avg_slot_uploads: 16,
            avg_bytes_exchanged: 300,
            avg_bytes_skipped: 200,
            avg_bytes_full: 1000,
            pipelined: true,
            demux_fallbacks: 0,
            batches: 8,
        };
        assert_eq!(report.unaccounted_uploads(), 0);
        // saved-by-delta = (full − skipped) − exchanged
        assert_eq!(report.avg_bytes_saved_by_delta(), 500);
        assert_eq!(report.driver(), "pipelined");
    }
}
