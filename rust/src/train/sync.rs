//! Bandwidth-lean replica synchronization: the sync plan, delta codecs,
//! and the coordinator's reusable mean accumulator.
//!
//! The averaging barrier in [`super::replica`] used to ship every
//! parameter leaf as full-width f32 in both directions. This module is
//! the "exchange less" layer that replaces that wire format:
//!
//! 1. **Sync plan** ([`SyncPlan`]) — computed per epoch from
//!    [`crate::freeze::sync_slot_partition`] (itself derived from
//!    `train_slot_bindings`, the executable input contract). Frozen
//!    leaves are bit-identical on every replica by construction — same
//!    initial upload, never stepped while frozen, averaged while
//!    trainable before any thaw — so they are never downloaded from the
//!    device and never cross the channel. The plan also prices the
//!    exchange (full-universe / skipped / raw-trainable bytes) so the
//!    barrier's byte counters are exact, not estimated.
//!
//! 2. **Delta codecs** ([`LeafDelta`]) — trainable leaves exchange as
//!    *deltas against the last broadcast mean* rather than raw tensors.
//!    Both sides of the channel keep a `last` baseline map updated only
//!    by the deterministic broadcast decode, so encoder and decoder can
//!    never disagree about the reference point.
//!
//!    The default **exact** codec is a *bit* delta: `xor = x.bits ^
//!    base.bits`, stored as 2-bit-tagged little-endian bytes (nearby
//!    floats share sign/exponent bits, so high XOR bytes are mostly
//!    zero). XOR is losslessly invertible, which is what keeps the
//!    2-replica trajectory bit-identical to the 1-replica run — an
//!    arithmetic f32 delta would not round-trip (`base + (x - base) ≠ x`
//!    in IEEE arithmetic). A per-leaf [`LeafDelta::Raw`] escape ships
//!    plain f32 bytes whenever the XOR encoding would not win, so a
//!    leaf's wire size never exceeds its raw size and the
//!    "saved-by-delta" counter stays non-negative.
//!
//!    The opt-in **q8** codec (`--sync-compress q8`) quantizes the
//!    *arithmetic* delta to int8 with one f32 scale per leaf (`scale =
//!    max|d| / 127`): ~4× smaller and lossy, so it gets a
//!    bounded-divergence bench (`bench_train_replicas`) instead of a
//!    bit-pin.
//!
//! 3. **Mean accumulator** ([`MeanState`]) — the coordinator folds
//!    contributions into a persistent accumulator allocated at the first
//!    barrier and reused for every later one (alloc-free steady state),
//!    sums in replica-index order (deterministic IEEE fold), divides
//!    once, and re-encodes the mean as a broadcast delta. The
//!    coordinator's own `last` is updated by *decoding that broadcast*,
//!    not by copying the mean — under q8 the parties agree on the
//!    dequantized mean, bit for bit, because they run the same decode.
//!
//! Wire-byte accounting counts encoded payload bytes only (tags +
//! payload for XOR, `4 + n` for q8, `4n` for raw); slot names and
//! channel framing are host-side bookkeeping, identical across codecs,
//! and deliberately excluded so the counters compare codecs honestly.

use crate::checkpoint::Params;
use crate::freeze::sync_slot_partition;
use crate::runtime::ArtifactMeta;
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Result};

/// Wire codec for the trainable-leaf deltas a barrier exchanges.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SyncCompress {
    /// Lossless XOR bit-deltas (with a raw-f32 escape per leaf). The
    /// default: averaging stays bit-identical to full-tensor exchange.
    #[default]
    Exact,
    /// Int8-quantized arithmetic deltas, one f32 scale per leaf. Lossy;
    /// covered by a bounded-divergence bench, not a bit-pin.
    Q8,
}

impl SyncCompress {
    /// Parse a CLI spelling. Accepts `exact`/`f32` and `q8`/`int8`.
    pub fn parse(s: &str) -> Option<SyncCompress> {
        match s.to_ascii_lowercase().as_str() {
            "exact" | "f32" => Some(SyncCompress::Exact),
            "q8" | "int8" => Some(SyncCompress::Q8),
            _ => None,
        }
    }

    /// Stable label for reports and bench tables.
    pub fn label(self) -> &'static str {
        match self {
            SyncCompress::Exact => "exact",
            SyncCompress::Q8 => "q8",
        }
    }
}

/// One leaf's encoded delta against the shared `last` baseline.
#[derive(Clone, Debug, PartialEq)]
pub enum LeafDelta {
    /// Raw little-endian f32 bytes — the baseline-free escape hatch used
    /// whenever an encoding would not beat `4n` bytes.
    Raw(Vec<u8>),
    /// Tag-packed XOR bit-delta: `ceil(n/4)` tag bytes (2 bits per
    /// element selecting 0/1/2/4 significant low-order bytes) followed
    /// by the significant bytes of each `x.bits ^ base.bits` word.
    Xor(Vec<u8>),
    /// Int8-quantized arithmetic delta: `value = base + scale * q[i]`.
    Q8 { scale: f32, q: Vec<i8> },
}

impl LeafDelta {
    /// Encoded payload size in bytes (what the byte counters meter).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            LeafDelta::Raw(b) | LeafDelta::Xor(b) => b.len() as u64,
            LeafDelta::Q8 { q, .. } => 4 + q.len() as u64,
        }
    }
}

/// Significant low-order byte count for each 2-bit XOR tag value.
const XOR_TAG_BYTES: [usize; 4] = [0, 1, 2, 4];

fn xor_tag(d: u32) -> u8 {
    if d == 0 {
        0
    } else if d < 1 << 8 {
        1
    } else if d < 1 << 16 {
        2
    } else {
        3
    }
}

fn xor_encode(x: &[f32], base: &[f32]) -> Vec<u8> {
    let n = x.len();
    let tag_len = n.div_ceil(4);
    let mut out = vec![0u8; tag_len];
    for (i, (&xv, &bv)) in x.iter().zip(base).enumerate() {
        let d = xv.to_bits() ^ bv.to_bits();
        let tag = xor_tag(d);
        out[i / 4] |= tag << ((i % 4) * 2);
        out.extend_from_slice(&d.to_le_bytes()[..XOR_TAG_BYTES[tag as usize]]);
    }
    out
}

/// Walk an XOR encoding, handing each element's index and XOR word to
/// `f`. Validates the payload is exactly consumed.
fn xor_decode_with(enc: &[u8], n: usize, mut f: impl FnMut(usize, u32)) -> Result<()> {
    let tag_len = n.div_ceil(4);
    ensure!(enc.len() >= tag_len, "xor delta truncated: {} < {tag_len} tag bytes", enc.len());
    let (tags, payload) = enc.split_at(tag_len);
    let mut pos = 0usize;
    for i in 0..n {
        let tag = (tags[i / 4] >> ((i % 4) * 2)) & 3;
        let nbytes = XOR_TAG_BYTES[tag as usize];
        let Some(src) = payload.get(pos..pos + nbytes) else {
            bail!("xor delta truncated at element {i}");
        };
        let mut b = [0u8; 4];
        b[..nbytes].copy_from_slice(src);
        pos += nbytes;
        f(i, u32::from_le_bytes(b));
    }
    ensure!(pos == payload.len(), "xor delta has {} trailing bytes", payload.len() - pos);
    Ok(())
}

fn raw_encode(x: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(x.len() * 4);
    for &v in x {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Encode one leaf's value `x` as a delta against `base` under `mode`.
///
/// Every path is capped at the raw size: if the chosen codec would not
/// beat `4n` bytes for this leaf it ships [`LeafDelta::Raw`] instead, so
/// `wire_bytes() <= 4 * x.len()` always holds and "bytes saved by delta"
/// can never go negative.
pub fn encode_leaf(x: &[f32], base: &[f32], mode: SyncCompress) -> LeafDelta {
    debug_assert_eq!(x.len(), base.len());
    let raw_bytes = x.len() * 4;
    match mode {
        SyncCompress::Exact => {
            let enc = xor_encode(x, base);
            if enc.len() < raw_bytes {
                LeafDelta::Xor(enc)
            } else {
                LeafDelta::Raw(raw_encode(x))
            }
        }
        SyncCompress::Q8 => {
            // scalar-ish leaves: 4 (scale) + n quantized bytes must beat 4n
            if 4 + x.len() >= raw_bytes {
                return LeafDelta::Raw(raw_encode(x));
            }
            let mut max = 0f32;
            for (&xv, &bv) in x.iter().zip(base) {
                max = max.max((xv - bv).abs());
            }
            let scale = if max == 0.0 { 0.0 } else { max / 127.0 };
            let q = if scale == 0.0 {
                vec![0i8; x.len()]
            } else {
                x.iter()
                    .zip(base)
                    .map(|(&xv, &bv)| ((xv - bv) / scale).round().clamp(-127.0, 127.0) as i8)
                    .collect()
            };
            LeafDelta::Q8 { scale, q }
        }
    }
}

/// Decode `delta` against the baseline held in `out`, in place: on entry
/// `out` is the `last` baseline, on exit it is the reconstructed value.
pub fn decode_leaf_apply(delta: &LeafDelta, out: &mut [f32]) -> Result<()> {
    match delta {
        LeafDelta::Raw(b) => {
            ensure!(
                b.len() == out.len() * 4,
                "raw delta: {} bytes for {} elems",
                b.len(),
                out.len()
            );
            for (v, c) in out.iter_mut().zip(b.chunks_exact(4)) {
                *v = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            Ok(())
        }
        LeafDelta::Xor(enc) => xor_decode_with(enc, out.len(), |i, d| {
            out[i] = f32::from_bits(out[i].to_bits() ^ d);
        }),
        LeafDelta::Q8 { scale, q } => {
            ensure!(q.len() == out.len(), "q8 delta: {} quants for {} elems", q.len(), out.len());
            for (v, &qi) in out.iter_mut().zip(q) {
                *v += scale * qi as f32;
            }
            Ok(())
        }
    }
}

/// Decode `delta` against `base` and *add* the reconstructed value into
/// `acc` — the coordinator's fold step, which never materializes the
/// contribution as a separate vector.
fn decode_leaf_add(delta: &LeafDelta, base: &[f32], acc: &mut [f32]) -> Result<()> {
    ensure!(base.len() == acc.len(), "baseline/accumulator length mismatch");
    match delta {
        LeafDelta::Raw(b) => {
            ensure!(
                b.len() == acc.len() * 4,
                "raw delta: {} bytes for {} elems",
                b.len(),
                acc.len()
            );
            for (a, c) in acc.iter_mut().zip(b.chunks_exact(4)) {
                *a += f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            Ok(())
        }
        LeafDelta::Xor(enc) => xor_decode_with(enc, acc.len(), |i, d| {
            acc[i] += f32::from_bits(base[i].to_bits() ^ d);
        }),
        LeafDelta::Q8 { scale, q } => {
            ensure!(q.len() == acc.len(), "q8 delta: {} quants for {} elems", q.len(), acc.len());
            for (i, &qi) in q.iter().enumerate() {
                acc[i] += base[i] + scale * qi as f32;
            }
            Ok(())
        }
    }
}

/// One direction of barrier traffic: encoded deltas for the exchanged
/// parameter leaves, plus their momenta when the momentum policy
/// averages them. Leaf order is the sync plan's order on both sides.
#[derive(Clone, Debug, Default)]
pub struct SyncFrame {
    pub params: Vec<(String, LeafDelta)>,
    pub momenta: Vec<(String, LeafDelta)>,
    /// Membership epoch: how many replicas the coordinator had evicted
    /// when it broadcast this frame (0 in contribution frames and for a
    /// healthy fleet). Monotonically non-decreasing across broadcasts —
    /// replicas assert this, because an out-of-order frame would desync
    /// every delta baseline. Host-side bookkeeping, not wire payload.
    pub membership: u64,
}

impl SyncFrame {
    /// Total encoded payload bytes in this frame.
    pub fn wire_bytes(&self) -> u64 {
        self.params
            .iter()
            .chain(&self.momenta)
            .map(|(_, d)| d.wire_bytes())
            .sum()
    }
}

/// What one epoch's barriers exchange and what they skip, priced in
/// bytes. Computed from the freeze partition of the epoch's train
/// artifact, so the plan tracks pattern swaps (a↔b) automatically.
#[derive(Clone, Debug)]
pub struct SyncPlan {
    /// Trainable param leaves that must cross the channel: `(name, elems)`.
    pub exchanged: Vec<(String, usize)>,
    /// Frozen param leaves that never cross the channel: `(name, elems)`.
    pub skipped: Vec<(String, usize)>,
    /// Whether momenta of the exchanged leaves ride along
    /// (MomentumPolicy::Average).
    pub momenta: bool,
}

impl SyncPlan {
    /// Build the plan for `meta`'s slot layout. `momenta` says whether
    /// the barrier also averages momentum buffers.
    pub fn of(meta: &ArtifactMeta, momenta: bool) -> SyncPlan {
        let (exchanged, skipped) = sync_slot_partition(meta);
        let count = |slots: Vec<&crate::runtime::ParamSlot>| {
            slots
                .into_iter()
                .map(|s| (s.name.clone(), s.shape.iter().product()))
                .collect()
        };
        SyncPlan { exchanged: count(exchanged), skipped: count(skipped), momenta }
    }

    fn exchanged_elems(&self) -> u64 {
        let params: u64 = self.exchanged.iter().map(|(_, n)| *n as u64).sum();
        if self.momenta {
            params * 2
        } else {
            params
        }
    }

    fn skipped_elems(&self) -> u64 {
        self.skipped.iter().map(|(_, n)| *n as u64).sum()
    }

    /// Bytes one barrier event would move if *every* parameter leaf —
    /// frozen included — shipped as raw f32 in both directions: the
    /// naive full-exchange reference the savings counters compare
    /// against.
    pub fn full_bytes(&self) -> u64 {
        (self.exchanged_elems() + self.skipped_elems()) * 4 * 2
    }

    /// Bytes one barrier event avoids by never moving frozen leaves
    /// (both directions).
    pub fn skipped_bytes(&self) -> u64 {
        self.skipped_elems() * 4 * 2
    }

    /// Bytes one barrier event would move shipping the exchanged leaves
    /// as raw f32 (both directions) — the delta codec's break-even
    /// ceiling, guaranteed by the per-leaf raw escape.
    pub fn raw_exchanged_bytes(&self) -> u64 {
        self.exchanged_elems() * 4 * 2
    }
}

/// A replica's side of the delta channel: the `last` baseline maps.
///
/// `last` starts as the initial params (and zero momenta — exactly what
/// the engine uploaded) and is mutated *only* by decoding broadcast
/// frames, the same deterministic step the coordinator applies to its
/// own copy. After [`apply_broadcast`](Self::apply_broadcast) the
/// decoded leaf value lives in `last` itself, ready both for the device
/// re-upload and as the next barrier's baseline — no scratch buffers.
pub struct ReplicaSyncState {
    last_params: Params,
    last_momenta: Params,
    compress: SyncCompress,
}

impl ReplicaSyncState {
    pub fn new(params: &Params, momenta: &Params, compress: SyncCompress) -> ReplicaSyncState {
        ReplicaSyncState {
            last_params: params.clone(),
            last_momenta: momenta.clone(),
            compress,
        }
    }

    /// Encode downloaded leaf values as deltas against `last`.
    pub fn encode_contribution(
        &self,
        params: &[(String, Tensor)],
        momenta: &[(String, Tensor)],
    ) -> Result<SyncFrame> {
        fn encode(
            leaves: &[(String, Tensor)],
            last: &Params,
            mode: SyncCompress,
        ) -> Result<Vec<(String, LeafDelta)>> {
            leaves
                .iter()
                .map(|(name, t)| {
                    let Some(base) = last.get(name) else {
                        bail!("sync baseline missing leaf {name}");
                    };
                    ensure!(
                        base.data().len() == t.data().len(),
                        "sync baseline for {name}: {} elems, downloaded {}",
                        base.data().len(),
                        t.data().len()
                    );
                    Ok((name.clone(), encode_leaf(t.data(), base.data(), mode)))
                })
                .collect()
        }
        Ok(SyncFrame {
            params: encode(params, &self.last_params, self.compress)?,
            momenta: encode(momenta, &self.last_momenta, self.compress)?,
        })
    }

    /// Decode a broadcast frame into the baselines, in place. Afterwards
    /// `last_param` / `last_momentum` hold the broadcast mean.
    pub fn apply_broadcast(&mut self, frame: &SyncFrame) -> Result<()> {
        apply_frame(frame, &mut self.last_params, &mut self.last_momenta)
    }

    pub fn last_param(&self, name: &str) -> Option<&Tensor> {
        self.last_params.get(name)
    }

    pub fn last_momentum(&self, name: &str) -> Option<&Tensor> {
        self.last_momenta.get(name)
    }
}

/// Decode every leaf of `frame` into its baseline tensor, in place.
fn apply_frame(frame: &SyncFrame, params: &mut Params, momenta: &mut Params) -> Result<()> {
    for (leaves, last) in [(&frame.params, params), (&frame.momenta, momenta)] {
        for (name, delta) in leaves {
            let Some(t) = last.get_mut(name) else {
                bail!("broadcast names unknown leaf {name}");
            };
            decode_leaf_apply(delta, t.data_mut())?;
        }
    }
    Ok(())
}

/// The coordinator's side: fold contribution frames into a reusable
/// accumulator, divide once, and re-encode the mean for broadcast.
///
/// The accumulator tensors are allocated at the first barrier that
/// touches each leaf and reused verbatim for every later barrier —
/// steady-state averaging allocates nothing but the outgoing frame.
pub struct MeanState {
    last_params: Params,
    last_momenta: Params,
    acc_params: Params,
    acc_momenta: Params,
    compress: SyncCompress,
}

impl MeanState {
    pub fn new(params: &Params, momenta: &Params, compress: SyncCompress) -> MeanState {
        MeanState {
            last_params: params.clone(),
            last_momenta: momenta.clone(),
            acc_params: Params::new(),
            acc_momenta: Params::new(),
            compress,
        }
    }

    /// Average one barrier's contributions (in replica-index order — the
    /// fold order is part of the determinism contract) and return the
    /// broadcast frame. Also applies the broadcast to the coordinator's
    /// own `last`, so both sides keep decoding against identical
    /// baselines — under q8 the baseline is the *dequantized* mean, the
    /// value the replicas will actually hold.
    pub fn average(&mut self, frames: &[SyncFrame]) -> Result<SyncFrame> {
        ensure!(!frames.is_empty(), "averaging zero contributions");
        fn names(v: &[(String, LeafDelta)]) -> Vec<&String> {
            v.iter().map(|(n, _)| n).collect()
        }
        let first = &frames[0];
        for f in &frames[1..] {
            ensure!(
                names(&f.params) == names(&first.params),
                "contributions disagree on the exchanged leaf set"
            );
            ensure!(
                names(&f.momenta) == names(&first.momenta),
                "contributions disagree on the exchanged momentum set"
            );
        }
        let mut out = SyncFrame::default();
        fold_group(
            frames,
            |f| &f.params,
            &self.last_params,
            &mut self.acc_params,
            self.compress,
            &mut out.params,
        )?;
        fold_group(
            frames,
            |f| &f.momenta,
            &self.last_momenta,
            &mut self.acc_momenta,
            self.compress,
            &mut out.momenta,
        )?;
        apply_frame(&out, &mut self.last_params, &mut self.last_momenta)?;
        Ok(out)
    }

    /// The coordinator's own copy of the fleet state after the last
    /// broadcast: every leaf the run ever exchanged holds the last
    /// broadcast mean, every frozen leaf its (never-moved) initial value
    /// — bit-identical to what any surviving replica's device holds
    /// right after a boundary barrier. This is the run's final state
    /// when replica 0, the designated state reporter, was evicted.
    pub fn final_state(&self) -> (Params, Params) {
        (self.last_params.clone(), self.last_momenta.clone())
    }

    #[cfg(test)]
    fn acc_param_ptr(&self, name: &str) -> Option<*const f32> {
        self.acc_params.get(name).map(|t| t.data().as_ptr())
    }
}

/// Fold one leaf group (params or momenta) of every contribution into
/// the persistent accumulator and emit the mean's broadcast encoding.
fn fold_group(
    frames: &[SyncFrame],
    pick: fn(&SyncFrame) -> &[(String, LeafDelta)],
    last: &Params,
    acc: &mut Params,
    compress: SyncCompress,
    dst: &mut Vec<(String, LeafDelta)>,
) -> Result<()> {
    let n = frames.len() as f32;
    for (li, (name, _)) in pick(&frames[0]).iter().enumerate() {
        let Some(base) = last.get(name) else {
            bail!("coordinator baseline missing leaf {name}");
        };
        let acc_t = acc.entry(name.clone()).or_insert_with(|| Tensor::zeros(base.shape()));
        ensure!(
            acc_t.data().len() == base.data().len(),
            "accumulator/baseline length mismatch for {name}"
        );
        acc_t.data_mut().fill(0.0);
        for f in frames {
            let (fname, delta) = &pick(f)[li];
            ensure!(fname == name, "contribution leaf order diverged at {name}");
            decode_leaf_add(delta, base.data(), acc_t.data_mut())?;
        }
        for v in acc_t.data_mut() {
            *v /= n;
        }
        dst.push((name.clone(), encode_leaf(acc_t.data(), base.data(), compress)));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(data: &[f32]) -> Tensor {
        Tensor::new(&[data.len()], data.to_vec())
    }

    #[test]
    fn compress_parses() {
        assert_eq!(SyncCompress::parse("exact"), Some(SyncCompress::Exact));
        assert_eq!(SyncCompress::parse("f32"), Some(SyncCompress::Exact));
        assert_eq!(SyncCompress::parse("Q8"), Some(SyncCompress::Q8));
        assert_eq!(SyncCompress::parse("int8"), Some(SyncCompress::Q8));
        assert_eq!(SyncCompress::parse("zstd"), None);
    }

    #[test]
    fn xor_delta_roundtrips_bit_exactly() {
        // nearby values (small XOR), identical values (zero XOR), wild
        // values (full-width XOR) and specials all must survive
        let base = vec![1.0f32, -2.5, 0.0, 3.25e-3, f32::MAX, 7.0, -0.0];
        let x = vec![1.0000001f32, -2.5, 1.0e9, 3.26e-3, f32::MIN_POSITIVE, 7.0, 0.0];
        let d = encode_leaf(&x, &base, SyncCompress::Exact);
        let mut out = base.clone();
        decode_leaf_apply(&d, &mut out).unwrap();
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&out), bits(&x));
        // identical leaves compress to tags only
        let same = encode_leaf(&base, &base, SyncCompress::Exact);
        assert_eq!(same.wire_bytes(), base.len().div_ceil(4) as u64);
    }

    #[test]
    fn exact_encoding_never_exceeds_raw_size() {
        // adversarial: every element's XOR needs all 4 bytes, so the
        // XOR form (tags + 4n) loses and the Raw escape must kick in
        let base = vec![1.0f32; 9];
        let x = vec![-3.7e8f32; 9];
        let d = encode_leaf(&x, &base, SyncCompress::Exact);
        assert!(matches!(d, LeafDelta::Raw(_)));
        assert_eq!(d.wire_bytes(), 9 * 4);
        let mut out = base.clone();
        decode_leaf_apply(&d, &mut out).unwrap();
        assert_eq!(out, x);
    }

    #[test]
    fn q8_delta_error_is_bounded_by_half_scale() {
        let base: Vec<f32> = (0..64).map(|i| (i as f32) * 0.1).collect();
        let x: Vec<f32> = base
            .iter()
            .enumerate()
            .map(|(i, v)| v + ((i * 37 % 19) as f32 - 9.0) * 1e-3)
            .collect();
        let d = encode_leaf(&x, &base, SyncCompress::Q8);
        let LeafDelta::Q8 { scale, .. } = &d else { panic!("expected q8") };
        assert_eq!(d.wire_bytes(), 4 + 64);
        let mut out = base.clone();
        decode_leaf_apply(&d, &mut out).unwrap();
        for (o, xv) in out.iter().zip(&x) {
            assert!((o - xv).abs() <= scale / 2.0 + f32::EPSILON, "{o} vs {xv}");
        }
        // zero delta encodes with zero scale and decodes to the baseline
        let z = encode_leaf(&base, &base, SyncCompress::Q8);
        let mut out = base.clone();
        decode_leaf_apply(&z, &mut out).unwrap();
        assert_eq!(out, base);
        // scalar-ish leaves fall back to raw (4 + n would not beat 4n)
        assert!(matches!(encode_leaf(&[2.0], &[1.0], SyncCompress::Q8), LeafDelta::Raw(_)));
    }

    fn frame_of(vals: &Params, last: &ReplicaSyncState) -> SyncFrame {
        let leaves: Vec<(String, Tensor)> =
            vals.iter().map(|(n, t)| (n.clone(), t.clone())).collect();
        last.encode_contribution(&leaves, &[]).unwrap()
    }

    #[test]
    fn identical_contributions_average_bit_exactly_through_the_codec() {
        // the parity pin's algebraic core: encode → fold → mean → encode
        // → decode of identical contributions must reproduce them bit
        // for bit on every party
        let init: Params = [("w".to_string(), tensor(&[0.5, -1.25, 3.0e-7, 42.0]))].into();
        let momenta = Params::new();
        let mut coord = MeanState::new(&init, &momenta, SyncCompress::Exact);
        let mut rep = ReplicaSyncState::new(&init, &momenta, SyncCompress::Exact);

        let stepped: Params = [("w".to_string(), tensor(&[0.4999, -1.2501, 2.9e-7, 41.0]))].into();
        let f = frame_of(&stepped, &rep);
        let bcast = coord.average(&[f.clone(), f]).unwrap();
        rep.apply_broadcast(&bcast).unwrap();
        let got = rep.last_param("w").unwrap().data();
        let want = stepped["w"].data();
        assert_eq!(
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // and the coordinator's own baseline agrees with the replica's
        assert_eq!(coord.last_params["w"].data(), got);
    }

    #[test]
    fn mean_is_elementwise_and_accumulator_is_reused() {
        let init: Params = [("w".to_string(), tensor(&[0.0, 0.0]))].into();
        let momenta = Params::new();
        let mut coord = MeanState::new(&init, &momenta, SyncCompress::Exact);
        let rep = ReplicaSyncState::new(&init, &momenta, SyncCompress::Exact);

        let a: Params = [("w".to_string(), tensor(&[1.0, 10.0]))].into();
        let b: Params = [("w".to_string(), tensor(&[3.0, 20.0]))].into();
        let bcast = coord.average(&[frame_of(&a, &rep), frame_of(&b, &rep)]).unwrap();
        let mut out = vec![0.0f32; 2];
        decode_leaf_apply(&bcast.params[0].1, &mut out).unwrap();
        assert_eq!(out, vec![2.0, 15.0]);

        // satellite: the second barrier folds into the same allocation —
        // steady-state averaging is alloc-free
        let p0 = coord.acc_param_ptr("w").unwrap();
        let mut rep2 = ReplicaSyncState::new(&init, &momenta, SyncCompress::Exact);
        rep2.apply_broadcast(&bcast).unwrap();
        let c: Params = [("w".to_string(), tensor(&[5.0, 5.0]))].into();
        let f2 = frame_of(&c, &rep2);
        coord.average(&[f2.clone(), f2]).unwrap();
        assert_eq!(p0, coord.acc_param_ptr("w").unwrap(), "accumulator reallocated");
    }

    #[test]
    fn mismatched_contributions_are_rejected() {
        let init: Params = [("w".to_string(), tensor(&[0.0]))].into();
        let momenta = Params::new();
        let mut coord = MeanState::new(&init, &momenta, SyncCompress::Exact);
        let rep = ReplicaSyncState::new(&init, &momenta, SyncCompress::Exact);
        let good = frame_of(&[("w".to_string(), tensor(&[1.0]))].into(), &rep);
        let renamed = SyncFrame {
            params: vec![("v".to_string(), good.params[0].1.clone())],
            ..Default::default()
        };
        assert!(coord.average(&[good.clone(), renamed]).is_err());
        // unknown leaf in an otherwise well-formed frame
        let unknown = SyncFrame {
            params: vec![("v".to_string(), good.params[0].1.clone())],
            ..Default::default()
        };
        assert!(coord.average(&[unknown.clone(), unknown]).is_err());
    }

    #[test]
    fn q8_parties_agree_on_the_dequantized_mean() {
        // lossy path: replicas and coordinator must still hold identical
        // baselines after a barrier, or later deltas desync
        let params_of = |data: &[f32]| -> Params { [("w".to_string(), tensor(data))].into() };
        let init = params_of(&[1.0, -1.0, 0.5, 2.0, -0.25, 0.0, 8.0, 1.5]);
        let momenta = Params::new();
        let mut coord = MeanState::new(&init, &momenta, SyncCompress::Q8);
        let mut r0 = ReplicaSyncState::new(&init, &momenta, SyncCompress::Q8);
        let mut r1 = ReplicaSyncState::new(&init, &momenta, SyncCompress::Q8);

        let s0 = params_of(&[1.1, -0.9, 0.6, 1.9, -0.3, 0.1, 7.9, 1.4]);
        let s1 = params_of(&[0.9, -1.1, 0.4, 2.1, -0.2, -0.1, 8.1, 1.6]);
        let bcast = coord.average(&[frame_of(&s0, &r0), frame_of(&s1, &r1)]).unwrap();
        r0.apply_broadcast(&bcast).unwrap();
        r1.apply_broadcast(&bcast).unwrap();
        let bits = |t: &Tensor| t.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(r0.last_param("w").unwrap()), bits(r1.last_param("w").unwrap()));
        assert_eq!(bits(r0.last_param("w").unwrap()), bits(&coord.last_params["w"]));
        // and the dequantized mean tracks the exact mean within the
        // stacked quantization error: half a step per contribution plus
        // half a step for the broadcast. Deltas here are <= 0.2, so each
        // scale is <= 0.2/127 and the stack is well under 3e-3.
        for (i, v) in r0.last_param("w").unwrap().data().iter().enumerate() {
            let exact = (s0["w"].data()[i] + s1["w"].data()[i]) / 2.0;
            assert!((v - exact).abs() <= 3e-3, "elem {i}: {v} vs exact {exact}");
        }
    }

    #[test]
    fn truncated_encodings_are_rejected() {
        let base = vec![1.0f32, 2.0, 3.0, 4.0, 5.0];
        let x = vec![1.5f32, 2.5, 3.5, 4.5, 5.5];
        let LeafDelta::Xor(enc) = encode_leaf(&x, &base, SyncCompress::Exact) else {
            panic!("expected xor")
        };
        let mut out = base.clone();
        let cut = LeafDelta::Xor(enc[..enc.len() - 1].to_vec());
        assert!(decode_leaf_apply(&cut, &mut out).is_err());
        let mut padded = enc.clone();
        padded.push(0);
        assert!(decode_leaf_apply(&LeafDelta::Xor(padded), &mut out).is_err());
        let raw = LeafDelta::Raw(vec![0u8; 7]);
        assert!(decode_leaf_apply(&raw, &mut out).is_err());
        let q8 = LeafDelta::Q8 { scale: 1.0, q: vec![0; 3] };
        assert!(decode_leaf_apply(&q8, &mut out).is_err());
    }
}
