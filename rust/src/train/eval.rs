//! Overlapped per-epoch evaluation: test-set accuracy computed on a side
//! thread while the next epoch's steps already run.
//!
//! The serial resident path blocks the whole epoch loop on evaluation
//! (`Engine::evaluate`). PJRT handles are not `Send`, so the overlap cannot
//! share the trainer's client: instead the worker owns its *own* PJRT
//! client and compiled infer executable (exactly the serving-engine
//! pattern), and each epoch hands it a host **snapshot** of the resident
//! parameters (`Params` is plain `Send` data). The snapshot download is the
//! one synchronous cost on the engine thread — and it is amortized: the
//! trainer hands the *same* snapshot to the async checkpoint writer
//! ([`crate::train::CheckpointWriter`]) when epoch checkpointing is on.
//! The eval itself — upload snapshot, stream test batches, count correct —
//! overlaps with epoch N+1.
//!
//! Determinism: the worker runs the same artifact on the same test batches
//! in the same order as `Engine::evaluate`, so the reported accuracy is
//! bit-identical to the inline eval's (XLA CPU compilation is
//! deterministic; pinned in `integration_train_resident`).
//!
//! Join points are the *caller's* job: [`crate::coordinator::Trainer`]
//! collects finished epochs at each epoch boundary (the next freeze-pattern
//! swap) and drains the tail after the last epoch.

use crate::checkpoint::Params;
use crate::data::Dataset;
use crate::obs::Tracer;
use crate::runtime::{ArtifactMeta, Executable, Runtime};
use crate::train::ResidentParams;
use crate::util::stats::count_correct;
use anyhow::{anyhow, bail, Result};
use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::thread;

/// One eval request: the epoch index it reports for plus the parameter
/// snapshot to evaluate.
struct Job {
    epoch: usize,
    params: Params,
}

/// A finished (or failed) evaluation.
type Outcome = (usize, Result<f64, String>);

/// Side-thread evaluator over snapshots of the resident parameters.
pub struct EvalWorker {
    tx: Option<mpsc::Sender<Job>>,
    rx: mpsc::Receiver<Outcome>,
    join: Option<thread::JoinHandle<()>>,
    /// Submitted but not yet collected epochs.
    pending: usize,
}

impl EvalWorker {
    /// Spawn the worker: it creates its own PJRT client and compiles the
    /// infer artifact at `hlo_path` *on the side thread*, so even that
    /// startup cost overlaps with the first epoch's steps. Each evaluation
    /// records an `eval` span on `tracer` (in the worker's own lane, which
    /// is what shows the overlap in the exported trace).
    pub fn spawn(
        hlo_path: PathBuf,
        meta: ArtifactMeta,
        test: Arc<Dataset>,
        tracer: Tracer,
    ) -> EvalWorker {
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let (out_tx, out_rx) = mpsc::channel::<Outcome>();
        let join = thread::Builder::new()
            .name("lrta-train-eval".into())
            .spawn(move || {
                let init = (|| -> Result<(Runtime, Executable)> {
                    let rt = Runtime::cpu()?;
                    let exe = rt.load_hlo(&hlo_path)?;
                    Ok((rt, exe))
                })();
                match init {
                    Ok((rt, exe)) => {
                        while let Ok(job) = job_rx.recv() {
                            let span = tracer.start();
                            let acc = evaluate_snapshot(&rt, &exe, &meta, &job.params, &test)
                                .map_err(|e| format!("{e:#}"));
                            tracer.end(span, "train", "eval");
                            if out_tx.send((job.epoch, acc)).is_err() {
                                break; // trainer gone — nothing left to report to
                            }
                        }
                    }
                    Err(e) => {
                        // startup failed: answer every job with the error so
                        // the trainer surfaces it instead of hanging
                        let msg = format!("eval worker failed to start: {e:#}");
                        while let Ok(job) = job_rx.recv() {
                            if out_tx.send((job.epoch, Err(msg.clone()))).is_err() {
                                break;
                            }
                        }
                    }
                }
            })
            .expect("spawn eval worker thread");
        EvalWorker { tx: Some(job_tx), rx: out_rx, join: Some(join), pending: 0 }
    }

    /// Queue one epoch's snapshot for evaluation (non-blocking).
    pub fn submit(&mut self, epoch: usize, params: Params) -> Result<()> {
        let tx = self.tx.as_ref().ok_or_else(|| anyhow!("eval worker shut down"))?;
        tx.send(Job { epoch, params }).map_err(|_| anyhow!("eval worker died"))?;
        self.pending += 1;
        Ok(())
    }

    /// Collect every evaluation that has already finished, without blocking
    /// — the per-epoch-boundary join point.
    pub fn try_collect(&mut self) -> Result<Vec<(usize, f64)>> {
        let mut out = Vec::new();
        while self.pending > 0 {
            match self.rx.try_recv() {
                Ok((epoch, acc)) => {
                    self.pending -= 1;
                    out.push((epoch, acc.map_err(|e| anyhow!(e))?));
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    bail!("eval worker died with {} evaluations pending", self.pending)
                }
            }
        }
        Ok(out)
    }

    /// Block until every submitted epoch has been evaluated — the
    /// end-of-run join point.
    pub fn drain(&mut self) -> Result<Vec<(usize, f64)>> {
        let mut out = Vec::new();
        while self.pending > 0 {
            match self.rx.recv() {
                Ok((epoch, acc)) => {
                    self.pending -= 1;
                    out.push((epoch, acc.map_err(|e| anyhow!(e))?));
                }
                Err(_) => bail!("eval worker died with {} evaluations pending", self.pending),
            }
        }
        Ok(out)
    }
}

impl Drop for EvalWorker {
    fn drop(&mut self) {
        // closing the job channel ends the worker loop; join so the thread
        // (and its PJRT client) never outlives the trainer run
        self.tx.take();
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

/// The eval math, shared shape with `Engine::evaluate`: upload the snapshot
/// once, then per test batch upload only `x` and count correct argmaxes.
/// Drops the partial final batch (constant AOT batch shape) like every
/// other evaluation path.
fn evaluate_snapshot(
    rt: &Runtime,
    exe: &Executable,
    meta: &ArtifactMeta,
    params: &Params,
    data: &Dataset,
) -> Result<f64> {
    let slots = || meta.trainable.iter().chain(meta.frozen.iter());
    let resident = ResidentParams::upload_for_slots(rt, params, slots())?;
    let ordered = resident.ordered(slots())?;
    let x_dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
    let batch = meta.batch;
    let mut correct = 0usize;
    let mut total = 0usize;
    for bi in 0..data.len() / batch {
        let (xs, ys) = data.batch(bi * batch, batch);
        let x_buf = rt.upload(&xla::Literal::vec1(&xs).reshape(&x_dims)?)?;
        let mut refs = ordered.clone();
        refs.push(&x_buf);
        let outs = exe.run_buffers(&refs)?;
        let mut lits = Executable::buffer_to_literals(&outs[0])?;
        let logits = crate::runtime::literal_to_tensor(&lits.swap_remove(0))?;
        correct += count_correct(logits.data(), logits.shape()[1], &ys);
        total += ys.len();
    }
    Ok(correct as f64 / total.max(1) as f64)
}
