//! Batch prefetch: the next batch's shuffle + gather-copy is assembled on
//! a worker thread while the current step executes on device.
//!
//! XLA handles (`Literal` / `PjRtBuffer`) are not `Send`, so the stage
//! produces plain host vectors and the engine thread materializes the
//! literal right before upload — the host-side assembly (the
//! [`BatchIter`] permutation walk and per-sample memcpy) is what overlaps
//! with device compute. Batch *order* is exactly `BatchIter`'s for the
//! same epoch seed: the channel is FIFO, so prefetched runs stay
//! bit-identical to the literal baseline.

use crate::data::{BatchIter, Dataset};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

/// How many assembled batches may wait in the channel. Depth 2 keeps one
/// batch in flight while the next assembles without buffering a whole
/// epoch of images.
const PIPELINE_DEPTH: usize = 2;

/// A one-epoch background batch producer.
pub struct Prefetcher {
    rx: Option<mpsc::Receiver<(Vec<f32>, Vec<i32>)>>,
    join: Option<thread::JoinHandle<()>>,
}

impl Prefetcher {
    /// Start assembling the epoch's batches (shuffled by `epoch_seed`,
    /// partial final batch dropped — same contract as [`BatchIter`]).
    pub fn start(data: Arc<Dataset>, batch: usize, epoch_seed: u64) -> Prefetcher {
        let (tx, rx) = mpsc::sync_channel(PIPELINE_DEPTH);
        let join = thread::Builder::new()
            .name("lrta-train-prefetch".into())
            .spawn(move || {
                for b in BatchIter::new(&data, batch, epoch_seed) {
                    // a dropped receiver (engine error mid-epoch) just ends
                    // the producer early
                    if tx.send(b).is_err() {
                        break;
                    }
                }
            })
            .expect("spawn prefetch thread");
        Prefetcher { rx: Some(rx), join: Some(join) }
    }

    /// Next assembled `(xs, ys)` batch; `None` once the epoch is exhausted.
    pub fn next_batch(&mut self) -> Option<(Vec<f32>, Vec<i32>)> {
        self.rx.as_ref().and_then(|rx| rx.recv().ok())
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // close the channel first so a producer blocked in `send` unblocks,
        // then join so the thread never outlives the epoch that spawned it
        self.rx.take();
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetch_order_matches_batch_iter() {
        let data = Arc::new(Dataset::synthetic(64, 11));
        let direct: Vec<(Vec<f32>, Vec<i32>)> = BatchIter::new(&data, 16, 3).collect();
        let mut pf = Prefetcher::start(Arc::clone(&data), 16, 3);
        let mut got = Vec::new();
        while let Some(b) = pf.next_batch() {
            got.push(b);
        }
        assert_eq!(got.len(), direct.len());
        for (g, d) in got.iter().zip(&direct) {
            assert_eq!(g.1, d.1);
            assert_eq!(g.0, d.0);
        }
    }

    #[test]
    fn dropping_mid_epoch_does_not_hang() {
        let data = Arc::new(Dataset::synthetic(256, 12));
        let mut pf = Prefetcher::start(data, 16, 0);
        let _ = pf.next_batch();
        drop(pf); // producer blocked on a full channel must unblock + join
    }
}
