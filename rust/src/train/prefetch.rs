//! Batch prefetch: the next batch's shuffle + gather-copy is assembled on
//! a worker thread while the current step executes on device.
//!
//! XLA handles (`Literal` / `PjRtBuffer`) are not `Send`, so the stage
//! produces plain host vectors and the engine thread materializes the
//! literal right before upload — the host-side assembly (the
//! [`BatchIter`] permutation walk and per-sample memcpy) is what overlaps
//! with device compute. Batch *order* is exactly `BatchIter`'s for the
//! same epoch seed: the channel is FIFO, so prefetched runs stay
//! bit-identical to the literal baseline.

use crate::data::{
    epoch_order, BatchIter, DataSource, Dataset, Shard, StreamingProvider, IMAGE_ELEMS,
};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

/// How many assembled batches may wait in the channel. Depth 2 keeps one
/// batch in flight while the next assembles without buffering a whole
/// epoch of images.
const PIPELINE_DEPTH: usize = 2;

/// A one-epoch background batch producer.
pub struct Prefetcher {
    rx: Option<mpsc::Receiver<(Vec<f32>, Vec<i32>)>>,
    join: Option<thread::JoinHandle<()>>,
}

impl Prefetcher {
    /// Start assembling the epoch's batches (shuffled by `epoch_seed`,
    /// partial final batch dropped — same contract as [`BatchIter`]).
    pub fn start(data: Arc<Dataset>, batch: usize, epoch_seed: u64) -> Prefetcher {
        Self::start_sharded(data, batch, epoch_seed, Shard::full())
    }

    /// Like [`Prefetcher::start`], but assembling only `shard`'s round-robin
    /// slice of the epoch ([`BatchIter::new_sharded`]) — the data-parallel
    /// replicas each prefetch their own disjoint shard. The channel is FIFO
    /// and the shuffle is keyed by `epoch_seed` alone, so a sharded
    /// prefetched run is deterministic and batch-identical to iterating
    /// `BatchIter::new_sharded` inline.
    pub fn start_sharded(
        data: Arc<Dataset>,
        batch: usize,
        epoch_seed: u64,
        shard: Shard,
    ) -> Prefetcher {
        Self::spawn_producer(move |tx| {
            for b in BatchIter::new_sharded(&data, batch, epoch_seed, shard) {
                // fault seam: the worker has no error channel, so an
                // `error` directive escalates to a worker panic, which
                // `next_batch` re-raises on the engine thread
                if let Err(e) = crate::faults::hit(crate::faults::Seam::Prefetch, "") {
                    panic!("{e}");
                }
                // a dropped receiver (engine error mid-epoch) just ends
                // the producer early
                if tx.send(b).is_err() {
                    break;
                }
            }
        })
    }

    /// Start the producer for whichever side of a [`DataSource`] is live:
    /// in-memory sources run the classic [`BatchIter`] walk, streamed
    /// sources fetch chunks from storage with a fetch-ahead window. Batch
    /// order and contents are bit-identical either way — both paths index
    /// the one [`epoch_order`] permutation and streamed samples round-trip
    /// f32 values exactly.
    pub fn start_source(
        source: &DataSource,
        batch: usize,
        epoch_seed: u64,
        shard: Shard,
    ) -> Prefetcher {
        match source {
            DataSource::Memory(data) => {
                Self::start_sharded(Arc::clone(data), batch, epoch_seed, shard)
            }
            DataSource::Streamed(provider) => {
                Self::start_streaming(Arc::clone(provider), batch, epoch_seed, shard)
            }
        }
    }

    /// Like [`Prefetcher::start_sharded`], but assembling batches from a
    /// storage-backed corpus. Before assembling batch `b`, the worker
    /// pre-touches the chunks of batches `b..=b+fetch_ahead` (the
    /// provider's [`StreamingProvider::fetch_ahead`] window), so a chunk
    /// fetch that stalls — a slow object store, or a `storage_get:stall`
    /// fault — overlaps with the engine consuming already-queued batches
    /// instead of serializing behind it. Storage errors have no channel of
    /// their own: they escalate to a worker panic that
    /// [`Prefetcher::next_batch`] re-raises on the engine thread, exactly
    /// like the `prefetch` fault seam.
    pub fn start_streaming(
        provider: Arc<StreamingProvider>,
        batch: usize,
        epoch_seed: u64,
        shard: Shard,
    ) -> Prefetcher {
        Self::spawn_producer(move |tx| {
            let order = epoch_order(provider.len(), epoch_seed);
            let num_batches = shard.num_batches(provider.len() / batch);
            let window = provider.fetch_ahead();
            // next shard-local batch whose chunks have been pre-touched
            let mut touched = 0usize;
            for cursor in 0..num_batches {
                if let Err(e) = crate::faults::hit(crate::faults::Seam::Prefetch, "") {
                    panic!("{e}");
                }
                let ahead = (cursor + window).min(num_batches - 1);
                while touched <= ahead {
                    let g = touched * shard.count + shard.index;
                    for &idx in &order[g * batch..(g + 1) * batch] {
                        if let Err(e) = provider.prefetch_chunk(provider.chunk_of(idx)) {
                            panic!("streaming prefetch: {e:#}");
                        }
                    }
                    touched += 1;
                }
                let global = cursor * shard.count + shard.index;
                let mut xs = Vec::with_capacity(batch * IMAGE_ELEMS);
                let mut ys = Vec::with_capacity(batch);
                for &idx in &order[global * batch..(global + 1) * batch] {
                    if let Err(e) = provider.append_sample(idx, &mut xs, &mut ys) {
                        panic!("streaming batch assembly: {e:#}");
                    }
                }
                if tx.send((xs, ys)).is_err() {
                    break;
                }
            }
        })
    }

    fn spawn_producer(
        produce: impl FnOnce(mpsc::SyncSender<(Vec<f32>, Vec<i32>)>) + Send + 'static,
    ) -> Prefetcher {
        let (tx, rx) = mpsc::sync_channel(PIPELINE_DEPTH);
        let join = thread::Builder::new()
            .name("lrta-train-prefetch".into())
            .spawn(move || produce(tx))
            .expect("spawn prefetch thread");
        Prefetcher { rx: Some(rx), join: Some(join) }
    }

    /// Next assembled `(xs, ys)` batch; `None` once the epoch is exhausted.
    ///
    /// A worker panic must not masquerade as a short epoch: the channel
    /// disconnecting looks identical to normal exhaustion from the receive
    /// side, so on disconnect the worker is joined right here and its panic
    /// payload re-raised on the engine thread ([`std::panic::resume_unwind`])
    /// instead of silently ending the epoch early.
    pub fn next_batch(&mut self) -> Option<(Vec<f32>, Vec<i32>)> {
        match self.rx.as_ref()?.recv() {
            Ok(b) => Some(b),
            Err(_) => {
                // producer gone: either finished (clean join) or panicked
                self.rx.take();
                self.join_propagating();
                None
            }
        }
    }

    /// Join the worker if it is still attached; re-raise its panic, if any.
    fn join_propagating(&mut self) {
        if let Some(join) = self.join.take() {
            if let Err(payload) = join.join() {
                std::panic::resume_unwind(payload);
            }
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // close the channel first so a producer blocked in `send` unblocks,
        // then join so the thread never outlives the epoch that spawned it.
        // A worker panic is swallowed here only when this drop is itself
        // part of an unwind (a double panic would abort); on the normal
        // path `next_batch` already re-raised it.
        self.rx.take();
        if let Some(join) = self.join.take() {
            match join.join() {
                Ok(()) => {}
                Err(payload) if !std::thread::panicking() => {
                    std::panic::resume_unwind(payload)
                }
                Err(_) => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetch_order_matches_batch_iter() {
        let data = Arc::new(Dataset::synthetic(64, 11));
        let direct: Vec<(Vec<f32>, Vec<i32>)> = BatchIter::new(&data, 16, 3).collect();
        let mut pf = Prefetcher::start(Arc::clone(&data), 16, 3);
        let mut got = Vec::new();
        while let Some(b) = pf.next_batch() {
            got.push(b);
        }
        assert_eq!(got.len(), direct.len());
        for (g, d) in got.iter().zip(&direct) {
            assert_eq!(g.1, d.1);
            assert_eq!(g.0, d.0);
        }
    }

    #[test]
    fn sharded_prefetch_is_deterministic_and_matches_batch_iter() {
        let data = Arc::new(Dataset::synthetic(96, 17));
        for index in 0..3 {
            let shard = Shard::of(index, 3);
            let direct: Vec<(Vec<f32>, Vec<i32>)> =
                BatchIter::new_sharded(&data, 16, 5, shard).collect();
            for _ in 0..2 {
                // two prefetched runs: both must reproduce the inline
                // iteration batch-for-batch, in order
                let mut pf = Prefetcher::start_sharded(Arc::clone(&data), 16, 5, shard);
                let mut got = Vec::new();
                while let Some(b) = pf.next_batch() {
                    got.push(b);
                }
                assert_eq!(got, direct, "shard {index}");
            }
        }
    }

    /// The bit-identity pin behind [`DataSource`]: an epoch streamed from
    /// an object store yields the *same* batches, in the same order, as
    /// the in-memory iterator — for every shard.
    #[test]
    fn streamed_batches_match_batch_iter_bit_for_bit() {
        let data = Dataset::synthetic(96, 23);
        let store: Arc<dyn crate::storage::Storage> =
            Arc::new(crate::storage::MemObject::new());
        crate::data::stream::publish(&store, "corpus", &data, 10).unwrap();
        let provider =
            Arc::new(crate::data::StreamingProvider::open(Arc::clone(&store), "corpus").unwrap());
        for (index, count) in [(0, 1), (0, 3), (1, 3), (2, 3)] {
            let shard = Shard::of(index, count);
            let direct: Vec<(Vec<f32>, Vec<i32>)> =
                BatchIter::new_sharded(&data, 16, 5, shard).collect();
            let source = DataSource::streamed(Arc::clone(&provider));
            let mut pf = Prefetcher::start_source(&source, 16, 5, shard);
            let mut got = Vec::new();
            while let Some(b) = pf.next_batch() {
                got.push(b);
            }
            assert_eq!(got, direct, "shard {index}/{count}");
        }
    }

    #[test]
    fn start_source_memory_matches_start_sharded() {
        let data = Arc::new(Dataset::synthetic(64, 31));
        let source = DataSource::memory(Arc::clone(&data));
        let direct: Vec<(Vec<f32>, Vec<i32>)> = BatchIter::new(&data, 16, 2).collect();
        let mut pf = Prefetcher::start_source(&source, 16, 2, Shard::full());
        let mut got = Vec::new();
        while let Some(b) = pf.next_batch() {
            got.push(b);
        }
        assert_eq!(got, direct);
    }

    #[test]
    fn streaming_fetch_error_propagates_as_panic() {
        let data = Dataset::synthetic(32, 41);
        let store: Arc<dyn crate::storage::Storage> =
            Arc::new(crate::storage::MemObject::new());
        crate::data::stream::publish(&store, "corpus", &data, 8).unwrap();
        let provider =
            Arc::new(crate::data::StreamingProvider::open(Arc::clone(&store), "corpus").unwrap());
        // delete every chunk out from under the provider
        for key in store.list("chunks/").unwrap() {
            store.delete(&key).unwrap();
        }
        let mut pf = Prefetcher::start_streaming(provider, 16, 0, Shard::full());
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            while pf.next_batch().is_some() {}
        }))
        .expect_err("missing chunks must fail the epoch, not shorten it");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("streaming prefetch"), "unexpected payload: {msg}");
    }

    #[test]
    fn dropping_mid_epoch_does_not_hang() {
        let data = Arc::new(Dataset::synthetic(256, 12));
        let mut pf = Prefetcher::start(data, 16, 0);
        let _ = pf.next_batch();
        drop(pf); // producer blocked on a full channel must unblock + join
    }

    #[test]
    fn worker_panic_propagates_instead_of_ending_epoch_early() {
        let mut pf = Prefetcher::spawn_producer(|tx| {
            tx.send((vec![1.0], vec![1])).unwrap();
            panic!("prefetch worker exploded");
        });
        assert!(pf.next_batch().is_some());
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // the disconnect must re-raise the worker panic, not return None
            while pf.next_batch().is_some() {}
        }))
        .expect_err("worker panic must propagate to the engine thread");
        let msg = err
            .downcast_ref::<&str>()
            .copied()
            .map(String::from)
            .or_else(|| err.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("exploded"), "unexpected payload: {msg}");
    }

    #[test]
    fn clean_exhaustion_still_returns_none() {
        let data = Arc::new(Dataset::synthetic(32, 1));
        let mut pf = Prefetcher::start(data, 16, 0);
        let mut n = 0;
        while pf.next_batch().is_some() {
            n += 1;
        }
        assert_eq!(n, 2);
        // idempotent after exhaustion
        assert!(pf.next_batch().is_none());
    }
}
