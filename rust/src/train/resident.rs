//! Device-resident parameter state: named tensors that live in PJRT
//! buffers from the first upload until the run's final host sync.
//!
//! [`ResidentParams`] is the shared building block — serving engines keep
//! one set per variant, [`Trainer::infer_fps`](crate::coordinator::Trainer)
//! measures against one, and the training engine composes two of them
//! ([`ResidentState`]: parameters ∪ momenta). Buffers are keyed by
//! parameter *name*; which executable input slot a buffer feeds is decided
//! per artifact by [`crate::freeze::train_slot_bindings`], so a freeze-pattern
//! swap (Algorithm 2, a↔b) re-binds the same buffers to the new slot
//! layout instead of moving anything across the host boundary.
//!
//! Upload accounting is explicit: `uploads()` only ever counts host→device
//! parameter transfers through this type; step outputs re-bind via
//! [`ResidentParams::rebind`] (a pure ownership move). The proof that a run
//! stayed buffer-to-buffer is this counter staying at the initial value
//! *together with* [`crate::runtime::Runtime::demux_fallbacks`] staying 0
//! (the fallback re-uploads step outputs outside this counter); both are
//! asserted in `rust/tests/integration_train_resident.rs`.

use crate::checkpoint::Params;
use crate::freeze::{train_slot_bindings, SlotRole};
use crate::runtime::{
    download_scalar, download_tensor, tensor_to_literal, ArtifactMeta, ParamSlot, Runtime,
};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A named set of device-resident tensors (uploaded once).
pub struct ResidentParams {
    bufs: BTreeMap<String, xla::PjRtBuffer>,
    uploads: usize,
}

impl ResidentParams {
    /// Upload every tensor of `params` to a device buffer.
    pub fn upload(rt: &Runtime, params: &Params) -> Result<ResidentParams> {
        let mut bufs = BTreeMap::new();
        for (name, t) in params {
            bufs.insert(name.clone(), rt.upload(&tensor_to_literal(t)?)?);
        }
        let uploads = bufs.len();
        Ok(ResidentParams { bufs, uploads })
    }

    /// Upload exactly the tensors an artifact's signature names (what a
    /// serving engine needs: its variant's slots, nothing else).
    pub fn upload_for_slots<'a, I>(
        rt: &Runtime,
        params: &Params,
        slots: I,
    ) -> Result<ResidentParams>
    where
        I: IntoIterator<Item = &'a ParamSlot>,
    {
        let mut bufs = BTreeMap::new();
        for slot in slots {
            let t = params
                .get(&slot.name)
                .ok_or_else(|| anyhow!("missing param {}", slot.name))?;
            bufs.insert(slot.name.clone(), rt.upload(&tensor_to_literal(t)?)?);
        }
        let uploads = bufs.len();
        Ok(ResidentParams { bufs, uploads })
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    /// Host→device parameter transfers performed so far. Re-binding step
    /// outputs never increments this.
    pub fn uploads(&self) -> usize {
        self.uploads
    }

    pub fn get(&self, name: &str) -> Option<&xla::PjRtBuffer> {
        self.bufs.get(name)
    }

    /// Buffers gathered in `slots` order — the executable input contract.
    pub fn ordered<'a, I>(&self, slots: I) -> Result<Vec<&xla::PjRtBuffer>>
    where
        I: IntoIterator<Item = &'a ParamSlot>,
    {
        slots
            .into_iter()
            .map(|s| {
                self.bufs
                    .get(&s.name)
                    .ok_or_else(|| anyhow!("no resident buffer for '{}'", s.name))
            })
            .collect()
    }

    /// Consume the set into a dense buffer list laid out in `slots` order —
    /// for engines whose binding never changes (serving): gather once at
    /// startup, then reuse the Vec batch after batch with no map lookups on
    /// the latency-measured path.
    pub fn into_ordered<'a, I>(mut self, slots: I) -> Result<Vec<xla::PjRtBuffer>>
    where
        I: IntoIterator<Item = &'a ParamSlot>,
    {
        let mut out = Vec::new();
        for slot in slots {
            out.push(
                self.bufs
                    .remove(&slot.name)
                    .ok_or_else(|| anyhow!("no resident buffer for '{}'", slot.name))?,
            );
        }
        Ok(out)
    }

    /// Re-bind `name` to a step-output buffer: pure ownership transfer of a
    /// buffer that already lives on device — no host traffic, no upload.
    pub fn rebind(&mut self, name: &str, buf: xla::PjRtBuffer) -> Result<()> {
        match self.bufs.get_mut(name) {
            Some(slot) => {
                *slot = buf;
                Ok(())
            }
            None => bail!("rebind of unknown resident buffer '{name}'"),
        }
    }

    /// Download the whole set back to host tensors (checkpointing / final
    /// state sync — the places host state is semantically required).
    pub fn download(&self) -> Result<Params> {
        let mut out = Params::new();
        for (name, buf) in &self.bufs {
            out.insert(name.clone(), download_tensor(buf)?);
        }
        Ok(out)
    }
}

/// Full training state on device: every parameter and every momentum of
/// the model, across all freeze patterns the schedule will use.
pub struct ResidentState {
    pub params: ResidentParams,
    pub momenta: ResidentParams,
}

impl ResidentState {
    /// Upload parameters and momenta once, before the first step.
    pub fn upload(rt: &Runtime, params: &Params, momenta: &Params) -> Result<ResidentState> {
        Ok(ResidentState {
            params: ResidentParams::upload(rt, params)?,
            momenta: ResidentParams::upload(rt, momenta)?,
        })
    }

    /// Gather one train step's parameter/momentum inputs in the artifact's
    /// slot order ([`train_slot_bindings`]); the caller appends the
    /// per-step `x`/`y`/`lr` buffers. Gathered per step, not cached: every
    /// step re-binds the trainable/momentum buffers, so yesterday's refs
    /// are stale by construction (the map walk is noise next to the step
    /// execution it feeds).
    pub fn step_inputs(&self, meta: &ArtifactMeta) -> Result<Vec<&xla::PjRtBuffer>> {
        let mut refs = Vec::with_capacity(2 * meta.trainable.len() + meta.frozen.len());
        for b in train_slot_bindings(meta) {
            let set = match b.role {
                SlotRole::Momentum => &self.momenta,
                SlotRole::Trainable | SlotRole::Frozen => &self.params,
            };
            refs.push(set.get(b.name).ok_or_else(|| {
                anyhow!("no resident {:?} buffer for '{}' ({})", b.role, b.name, meta.name)
            })?);
        }
        Ok(refs)
    }

    /// Absorb a step's demuxed outputs: the new trainable parameters and
    /// momenta re-bind in place (buffer ownership moves; step N+1 will read
    /// them straight from device), and the two trailing scalars (loss,
    /// correct-count) sync to host for the epoch record.
    pub fn absorb_step(
        &mut self,
        meta: &ArtifactMeta,
        outs: Vec<xla::PjRtBuffer>,
    ) -> Result<(f32, f32)> {
        let n_tr = meta.trainable.len();
        if outs.len() != 2 * n_tr + 2 {
            bail!(
                "train step '{}' produced {} outputs, expected {}",
                meta.name,
                outs.len(),
                2 * n_tr + 2
            );
        }
        let mut it = outs.into_iter();
        for slot in &meta.trainable {
            self.params.rebind(&slot.name, it.next().expect("length checked"))?;
        }
        for slot in &meta.trainable {
            self.momenta.rebind(&slot.name, it.next().expect("length checked"))?;
        }
        let loss = download_scalar(&it.next().expect("length checked"))?;
        let correct = download_scalar(&it.next().expect("length checked"))?;
        Ok((loss, correct))
    }

    /// Validate an epoch-boundary pattern swap: every slot of the new
    /// executable must already be resident (patterns of one variant span
    /// the same parameter universe — see [`crate::freeze::rebind_upload_set`]).
    /// Uploads nothing, by construction.
    pub fn rebind_for(&self, meta: &ArtifactMeta) -> Result<()> {
        for b in train_slot_bindings(meta) {
            let set = match b.role {
                SlotRole::Momentum => &self.momenta,
                SlotRole::Trainable | SlotRole::Frozen => &self.params,
            };
            if set.get(b.name).is_none() {
                bail!(
                    "pattern swap to '{}' requires non-resident buffer '{}'",
                    meta.name,
                    b.name
                );
            }
        }
        Ok(())
    }

    /// Total parameter/momentum uploads — constant after construction as
    /// long as every step and pattern swap stayed buffer-to-buffer.
    pub fn param_uploads(&self) -> usize {
        self.params.uploads() + self.momenta.uploads()
    }

    /// Download the full training state to host maps.
    pub fn sync(&self) -> Result<(Params, Params)> {
        Ok((self.params.download()?, self.momenta.download()?))
    }
}
