//! Device-resident parameter state: named tensors that live in PJRT
//! buffers from the first upload until the run's final host sync.
//!
//! [`ResidentParams`] is the shared building block — serving engines keep
//! one set per variant, [`Trainer::infer_fps`](crate::coordinator::Trainer)
//! measures against one, and the training engine composes two of them
//! ([`ResidentState`]: parameters ∪ momenta). Buffers are keyed by
//! parameter *name*; which executable input slot a buffer feeds is decided
//! per artifact by [`crate::freeze::train_slot_bindings`], so a freeze-pattern
//! swap (Algorithm 2, a↔b) re-binds the same buffers to the new slot
//! layout instead of moving anything across the host boundary.
//!
//! Upload accounting is explicit: `uploads()` only ever counts host→device
//! parameter transfers through this type; step outputs re-bind via
//! [`ResidentParams::rebind`] (a pure ownership move), and the data-parallel
//! averaging path replaces buffers via [`ResidentParams::upload_rebind`]
//! (counted). The proof that a run stayed buffer-to-buffer is this counter
//! staying at the initial value — plus exactly the documented averaging
//! budget on multi-replica runs — *together with*
//! [`crate::runtime::Runtime::demux_fallbacks`] staying 0 (the fallback
//! re-uploads step outputs outside this counter); both are asserted in
//! `rust/tests/integration_train_resident.rs` and
//! `rust/tests/integration_train_replicas.rs`.

use crate::checkpoint::Params;
use crate::freeze::{train_slot_bindings, SlotRole};
use crate::obs;
use crate::runtime::{
    builder, download_tensor, tensor_to_literal, ArtifactMeta, Executable, Manifest, ParamSlot,
    Runtime,
};
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A named set of device-resident tensors (uploaded once).
pub struct ResidentParams {
    bufs: BTreeMap<String, xla::PjRtBuffer>,
    uploads: obs::Counter,
}

impl ResidentParams {
    /// Upload every tensor of `params` to a device buffer.
    pub fn upload(rt: &Runtime, params: &Params) -> Result<ResidentParams> {
        let mut bufs = BTreeMap::new();
        for (name, t) in params {
            bufs.insert(name.clone(), rt.upload(&tensor_to_literal(t)?)?);
        }
        let uploads = obs::Counter::new();
        uploads.add(bufs.len() as u64);
        Ok(ResidentParams { bufs, uploads })
    }

    /// Upload exactly the tensors an artifact's signature names (what a
    /// serving engine needs: its variant's slots, nothing else).
    pub fn upload_for_slots<'a, I>(
        rt: &Runtime,
        params: &Params,
        slots: I,
    ) -> Result<ResidentParams>
    where
        I: IntoIterator<Item = &'a ParamSlot>,
    {
        let mut bufs = BTreeMap::new();
        for slot in slots {
            let t = params
                .get(&slot.name)
                .ok_or_else(|| anyhow!("missing param {}", slot.name))?;
            bufs.insert(slot.name.clone(), rt.upload(&tensor_to_literal(t)?)?);
        }
        let uploads = obs::Counter::new();
        uploads.add(bufs.len() as u64);
        Ok(ResidentParams { bufs, uploads })
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    /// Host→device parameter transfers performed so far. Re-binding step
    /// outputs never increments this.
    pub fn uploads(&self) -> usize {
        self.uploads.get() as usize
    }

    /// The upload counter handle, for registration on an
    /// [`obs::Registry`] — the registry then snapshots the *same* atomic
    /// this type increments, so registry values match `uploads()` exactly.
    pub fn upload_counter(&self) -> &obs::Counter {
        &self.uploads
    }

    pub fn get(&self, name: &str) -> Option<&xla::PjRtBuffer> {
        self.bufs.get(name)
    }

    /// Buffers gathered in `slots` order — the executable input contract.
    pub fn ordered<'a, I>(&self, slots: I) -> Result<Vec<&xla::PjRtBuffer>>
    where
        I: IntoIterator<Item = &'a ParamSlot>,
    {
        slots
            .into_iter()
            .map(|s| {
                self.bufs
                    .get(&s.name)
                    .ok_or_else(|| anyhow!("no resident buffer for '{}'", s.name))
            })
            .collect()
    }

    /// Consume the set into a dense buffer list laid out in `slots` order —
    /// for engines whose binding never changes (serving): gather once at
    /// startup, then reuse the Vec batch after batch with no map lookups on
    /// the latency-measured path.
    pub fn into_ordered<'a, I>(mut self, slots: I) -> Result<Vec<xla::PjRtBuffer>>
    where
        I: IntoIterator<Item = &'a ParamSlot>,
    {
        let mut out = Vec::new();
        for slot in slots {
            out.push(
                self.bufs
                    .remove(&slot.name)
                    .ok_or_else(|| anyhow!("no resident buffer for '{}'", slot.name))?,
            );
        }
        Ok(out)
    }

    /// Re-bind `name` to a step-output buffer: pure ownership transfer of a
    /// buffer that already lives on device — no host traffic, no upload.
    pub fn rebind(&mut self, name: &str, buf: xla::PjRtBuffer) -> Result<()> {
        match self.bufs.get_mut(name) {
            Some(slot) => {
                *slot = buf;
                Ok(())
            }
            None => bail!("rebind of unknown resident buffer '{name}'"),
        }
    }

    /// Replace a resident buffer with a fresh host tensor: one **counted**
    /// host→device parameter transfer followed by a rebind. This is the
    /// buffer-level parameter-averaging path of [`crate::train::replica`] —
    /// the one legitimate reason, after the initial upload, for a parameter
    /// to cross the host boundary — and counting it here is what lets tests
    /// pin that steps and freeze-pattern swaps contributed zero uploads on
    /// top of the documented averaging budget. The averaging barrier only
    /// calls this for the sync plan's exchanged leaves (the decoded
    /// broadcast mean from [`crate::train::sync`]); frozen leaves never
    /// reach it.
    pub fn upload_rebind(&mut self, rt: &Runtime, name: &str, t: &Tensor) -> Result<()> {
        let buf = rt.upload(&tensor_to_literal(t)?)?;
        self.uploads.inc();
        self.rebind(name, buf)
    }

    /// Download the whole set back to host tensors (checkpointing / final
    /// state sync — the places host state is semantically required).
    pub fn download(&self) -> Result<Params> {
        let mut out = Params::new();
        for (name, buf) in &self.bufs {
            out.insert(name.clone(), download_tensor(buf)?);
        }
        Ok(out)
    }
}

/// Full training state on device: every parameter and every momentum of
/// the model, across all freeze patterns the schedule will use.
pub struct ResidentState {
    pub params: ResidentParams,
    pub momenta: ResidentParams,
}

impl ResidentState {
    /// Upload parameters and momenta once, before the first step.
    pub fn upload(rt: &Runtime, params: &Params, momenta: &Params) -> Result<ResidentState> {
        Ok(ResidentState {
            params: ResidentParams::upload(rt, params)?,
            momenta: ResidentParams::upload(rt, momenta)?,
        })
    }

    /// Gather one train step's parameter/momentum inputs in the artifact's
    /// slot order ([`train_slot_bindings`]); the caller appends the
    /// per-step `x`/`y`/`lr` buffers. Gathered per step, not cached: every
    /// step re-binds the trainable/momentum buffers, so yesterday's refs
    /// are stale by construction (the map walk is noise next to the step
    /// execution it feeds).
    pub fn step_inputs(&self, meta: &ArtifactMeta) -> Result<Vec<&xla::PjRtBuffer>> {
        let mut refs = Vec::with_capacity(2 * meta.trainable.len() + meta.frozen.len());
        for b in train_slot_bindings(meta) {
            let set = match b.role {
                SlotRole::Momentum => &self.momenta,
                SlotRole::Trainable | SlotRole::Frozen => &self.params,
            };
            refs.push(set.get(b.name).ok_or_else(|| {
                anyhow!("no resident {:?} buffer for '{}' ({})", b.role, b.name, meta.name)
            })?);
        }
        Ok(refs)
    }

    /// Absorb a step's demuxed outputs: the new trainable parameters and
    /// momenta re-bind in place (buffer ownership moves; step N+1 will read
    /// them straight from device), and the two trailing scalars (loss,
    /// correct-count) sync to host for the epoch record (counted on the
    /// runtime's fetch channel).
    pub fn absorb_step(
        &mut self,
        rt: &Runtime,
        meta: &ArtifactMeta,
        outs: Vec<xla::PjRtBuffer>,
    ) -> Result<(f32, f32)> {
        let (loss_buf, correct_buf) = self.absorb_step_deferred(meta, outs)?;
        Ok((rt.fetch_scalar(&loss_buf)?, rt.fetch_scalar(&correct_buf)?))
    }

    /// The host-sync-free half of [`ResidentState::absorb_step`]: re-bind
    /// the new parameters/momenta and hand the loss/correct scalar *buffers*
    /// back without downloading them — the pipelined engine folds them into
    /// the device-resident [`MetricsAccumulator`] instead, so nothing
    /// crosses to the host per step.
    pub fn absorb_step_deferred(
        &mut self,
        meta: &ArtifactMeta,
        outs: Vec<xla::PjRtBuffer>,
    ) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer)> {
        let n_tr = meta.trainable.len();
        if outs.len() != 2 * n_tr + 2 {
            bail!(
                "train step '{}' produced {} outputs, expected {}",
                meta.name,
                outs.len(),
                2 * n_tr + 2
            );
        }
        let mut it = outs.into_iter();
        for slot in &meta.trainable {
            self.params.rebind(&slot.name, it.next().expect("length checked"))?;
        }
        for slot in &meta.trainable {
            self.momenta.rebind(&slot.name, it.next().expect("length checked"))?;
        }
        let loss = it.next().expect("length checked");
        let correct = it.next().expect("length checked");
        Ok((loss, correct))
    }

    /// Validate an epoch-boundary pattern swap: every slot of the new
    /// executable must already be resident (patterns of one variant span
    /// the same parameter universe — see [`crate::freeze::rebind_upload_set`]).
    /// Uploads nothing, by construction.
    pub fn rebind_for(&self, meta: &ArtifactMeta) -> Result<()> {
        for b in train_slot_bindings(meta) {
            let set = match b.role {
                SlotRole::Momentum => &self.momenta,
                SlotRole::Trainable | SlotRole::Frozen => &self.params,
            };
            if set.get(b.name).is_none() {
                bail!(
                    "pattern swap to '{}' requires non-resident buffer '{}'",
                    meta.name,
                    b.name
                );
            }
        }
        Ok(())
    }

    /// Total parameter/momentum uploads — constant after construction as
    /// long as every step and pattern swap stayed buffer-to-buffer.
    pub fn param_uploads(&self) -> usize {
        self.params.uploads() + self.momenta.uploads()
    }

    /// Download the full training state to host maps.
    pub fn sync(&self) -> Result<(Params, Params)> {
        Ok((self.params.download()?, self.momenta.download()?))
    }
}

/// Device-resident epoch-metric state: a `[loss_sum, correct_sum]` buffer
/// that absorbs every step's loss/correct scalar *on device* via the
/// accumulate computation, replacing the serial engine's 2-scalar-per-step
/// host sync with one fetch per epoch.
///
/// The computation comes from the AOT-lowered `metrics_acc` artifact when
/// the manifest carries one (`python/compile/aot.py` lowers it beside the
/// train steps) and otherwise from the always-available `XlaBuilder` form
/// ([`builder::metrics_accumulate_computation`]) — both implement the same
/// 5-input contract, so which one compiled is invisible to callers.
pub struct MetricsAccumulator {
    exe: Executable,
    /// `[1, 0]` / `[0, 1]` lane masks, uploaded once.
    e_loss: xla::PjRtBuffer,
    e_correct: xla::PjRtBuffer,
    /// The live accumulator buffer; re-binds to the accumulate output every
    /// step, exactly like the parameter buffers chain across train steps.
    acc: Option<xla::PjRtBuffer>,
    /// Steps folded in since the last [`MetricsAccumulator::reset`].
    steps: usize,
}

impl MetricsAccumulator {
    /// Compile the accumulate computation (manifest artifact if available,
    /// builder fallback) and upload the lane masks.
    pub fn create(rt: &Runtime, manifest: Option<&Manifest>) -> Result<MetricsAccumulator> {
        let from_manifest = manifest
            .and_then(|m| m.artifact("metrics_acc").ok().map(|meta| m.hlo_path(meta)))
            .and_then(|path| rt.load_hlo(path).ok());
        let exe = match from_manifest {
            Some(exe) => exe,
            None => rt.compile(&builder::metrics_accumulate_computation()?, "metrics_acc")?,
        };
        Ok(MetricsAccumulator {
            exe,
            e_loss: rt.upload(&xla::Literal::vec1(&[1.0f32, 0.0]))?,
            e_correct: rt.upload(&xla::Literal::vec1(&[0.0f32, 1.0]))?,
            acc: None,
            steps: 0,
        })
    }

    /// Zero the accumulator for a fresh epoch (one tiny upload).
    pub fn reset(&mut self, rt: &Runtime) -> Result<()> {
        self.acc = Some(rt.upload(&xla::Literal::vec1(&[0.0f32, 0.0]))?);
        self.steps = 0;
        Ok(())
    }

    /// Fold one step's loss/correct scalar buffers into the accumulator —
    /// an asynchronous device-side add; no host traffic.
    pub fn accumulate(
        &mut self,
        loss: &xla::PjRtBuffer,
        correct: &xla::PjRtBuffer,
    ) -> Result<()> {
        let acc = self.acc.as_ref().ok_or_else(|| anyhow!("metrics accumulator not reset"))?;
        let inputs: [&xla::PjRtBuffer; 5] = [acc, loss, correct, &self.e_loss, &self.e_correct];
        let mut outs = self.exe.run_buffers(&inputs)?;
        if outs.len() != 1 {
            bail!("metrics_acc produced {} outputs, expected 1", outs.len());
        }
        self.acc = Some(outs.swap_remove(0));
        self.steps += 1;
        Ok(())
    }

    /// Steps folded in since the last reset.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// The epoch's single host sync: download `(loss_sum, correct_sum)`
    /// (counted on the runtime's fetch channel).
    pub fn fetch(&self, rt: &Runtime) -> Result<(f32, f32)> {
        let acc = self.acc.as_ref().ok_or_else(|| anyhow!("metrics accumulator not reset"))?;
        let v = rt.fetch_f32s(acc)?;
        if v.len() != 2 {
            bail!("metrics accumulator holds {} values, expected 2", v.len());
        }
        Ok((v[0], v[1]))
    }
}
