//! `lrta::train` — the device-resident training engine.
//!
//! The paper's headline number is *training* throughput (+60% for rank
//! optimization + sequential freezing combined), and the literal-based
//! step loop ([`run_train_step`](crate::coordinator::run_train_step))
//! gives most of that back by round-tripping every parameter and momentum
//! tensor through host literals on every step. This module is the training
//! counterpart of the serving layer's residency work:
//!
//! ```text
//!   upload params+momenta once ──▶ [ResidentState]   (named device buffers)
//!                                        │
//!        ┌── epoch ──────────────────────▼──────────────────────────────┐
//!        │ [Prefetcher] assemble batch N+1 ║ step N executes on device  │
//!        │     x,y,lr upload (data only) ──▶ [train exe] run_buffers    │
//!        │     new params / momenta ◀────── demuxed output buffers      │
//!        │     (re-bound in place — step N+1 reads them directly)       │
//!        └───────────────────────────────────────────────────────────────┘
//!                                        │
//!             epoch boundary: Algorithm 2 swaps pattern a↔b —
//!             the *same* buffers re-bind to the new executable's
//!             slot layout (trainable↔frozen roles swap; nothing is
//!             downloaded or re-uploaded)
//!                                        │
//!             host sync only where semantics demand it: per-step
//!             loss/correct scalars, per-epoch eval (which itself runs
//!             on the resident buffers), checkpoint/final-state download
//! ```
//!
//! [`Engine`] owns the state and the step/epoch/eval primitives;
//! [`crate::coordinator::Trainer`] drives it (freeze schedule, records,
//! learning-rate schedule) and falls back to the literal baseline when
//! `TrainConfig::resident` is off (`lrta train --no-resident`), which is
//! what `bench_train_resident` compares against.
//!
//! On top of the resident engine sits the **overlapped pipeline**
//! (default; `--no-pipeline` restores the serial resident loop):
//! [`Engine::run_epoch_pipelined`] splits each step into dispatch/fetch
//! halves ([`crate::runtime::pipeline`]) and uploads batch N+1's `x`/`y`
//! into a [`DoubleBuffered`] staging pair while step N executes; epoch
//! loss/correct accumulate on device ([`MetricsAccumulator`]) and sync once
//! per epoch instead of twice per step; and per-epoch eval runs on a
//! parameter snapshot on a side thread ([`EvalWorker`]) while the next
//! epoch's steps proceed. All three overlaps preserve bit-identical
//! parameters and metrics (pinned in `integration_train_resident`).
//!
//! Scaling past one device happens in [`replica`]: N engine replicas —
//! each with its own PJRT client and [`ResidentState`] — step on disjoint
//! batch shards ([`crate::data::Shard`]) and periodically average their
//! trainable parameters at the buffer level, with freeze-pattern swaps
//! synchronized across replicas at epoch boundaries. The averaging
//! barrier rides the [`sync`] plan — frozen leaves never cross the
//! channel, trainable leaves ship as deltas against the last broadcast
//! mean (`--sync-compress q8` quantizes them) — and composes with either
//! epoch driver: replicas honor `TrainConfig::pipelined` through
//! [`Engine::run_epoch_pipelined_sharded`]. The per-epoch
//! snapshot the eval worker consumes is shared with [`CheckpointWriter`],
//! which persists epoch N's checkpoint on a side thread while epoch N+1
//! trains. See `ARCHITECTURE.md` at the repo root for the full system map.

pub mod ckpt;
pub mod eval;
pub mod prefetch;
pub mod replica;
pub mod resident;
pub mod sync;

pub use ckpt::CheckpointWriter;
pub use eval::EvalWorker;
pub use prefetch::Prefetcher;
pub use replica::{
    run_replicas, run_replicas_sourced, run_replicas_traced, MomentumPolicy, ReplicaConfig,
    ReplicaReport, ReplicaRun,
};
pub use resident::{MetricsAccumulator, ResidentParams, ResidentState};
pub use sync::{SyncCompress, SyncFrame, SyncPlan};

use crate::checkpoint::Params;
use crate::data::{DataSource, Dataset, Shard};
use crate::faults::{self, Seam};
use crate::metrics::ThroughputMeter;
use crate::obs::Tracer;
use crate::runtime::{literal_to_tensor, ArtifactMeta, DoubleBuffered, Executable, Runtime};
use crate::util::stats::count_correct;
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Instant;

/// Aggregates of one training epoch through the resident engine.
pub struct EpochStats {
    /// Mean per-batch training loss.
    pub loss: f64,
    /// Training accuracy over the epoch.
    pub train_acc: f64,
    /// Raw f32 sum behind `loss` (accumulated in step order) — what the
    /// data-parallel coordinator needs to weight shards without losing the
    /// bit-exactness the parity tests pin.
    pub loss_sum: f32,
    /// Raw f32 sum behind `train_acc` (correct-count, step order).
    pub correct_sum: f32,
    /// Samples consumed (batches × batch size; partial batches are dropped).
    pub samples: usize,
    /// Full batches executed this epoch.
    pub batches: usize,
    /// Per-step wall times (batch-upload + execute + scalar sync).
    pub meter: ThroughputMeter,
}

/// The device-resident training engine: buffer-to-buffer step chaining
/// with freeze-pattern rebinding. See the module docs for the data flow.
///
/// Two epoch drivers share the state:
/// - [`Engine::run_epoch`] — the serial PR-2 loop (upload, execute, sync 2
///   scalars, repeat);
/// - [`Engine::run_epoch_pipelined`] — the overlapped loop: dispatch step N
///   without blocking, upload batch N+1's `x`/`y` into the
///   [`DoubleBuffered`] staging pair while N executes, fold the loss/correct
///   scalars into the device-resident [`MetricsAccumulator`], and fetch the
///   epoch metrics exactly once at the epoch boundary.
pub struct Engine<'rt> {
    rt: &'rt Runtime,
    state: ResidentState,
    /// The learning rate is an executable input; its device buffer is
    /// cached per distinct value (it changes once per epoch at most).
    lr_cache: Option<(f32, xla::PjRtBuffer)>,
    /// On-device epoch metrics (pipelined path only; lazily compiled from
    /// the builder unless a manifest-lowered artifact was attached).
    metrics: Option<MetricsAccumulator>,
    /// Step-lifecycle span recorder (no-op unless [`Engine::set_tracer`]
    /// installed an enabled one).
    tracer: Tracer,
    /// Scope label for the fault-injection seams ([`crate::faults`]):
    /// empty for single-engine runs, `replica{i}` inside a replica fleet.
    fault_scope: String,
}

impl<'rt> Engine<'rt> {
    /// Upload the full training state (all parameters, all momenta) once.
    pub fn upload(rt: &'rt Runtime, params: &Params, momenta: &Params) -> Result<Engine<'rt>> {
        Ok(Engine {
            rt,
            state: ResidentState::upload(rt, params, momenta)?,
            lr_cache: None,
            metrics: None,
            tracer: Tracer::default(),
            fault_scope: String::new(),
        })
    }

    /// Install a span recorder: the pipelined epoch records
    /// `prefetch_wait` / `upload` / `dispatch` / `fetch` spans per step
    /// (`lrta train --trace-out`).
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// Label this engine's fault-injection seams (e.g. `replica1`) so a
    /// scoped `--faults` directive can target one member of a fleet.
    pub fn set_fault_scope(&mut self, scope: impl Into<String>) {
        self.fault_scope = scope.into();
    }

    /// Attach a pre-built metrics accumulator (e.g. compiled from the
    /// manifest's AOT-lowered `metrics_acc` artifact). Without this, the
    /// pipelined epoch lazily compiles the `XlaBuilder` form on first use.
    pub fn attach_metrics(&mut self, metrics: MetricsAccumulator) {
        self.metrics = Some(metrics);
    }

    pub fn state(&self) -> &ResidentState {
        &self.state
    }

    /// Mutable access to the resident state — the replica averaging path
    /// replaces trainable buffers in place via
    /// [`ResidentParams::upload_rebind`] between steps.
    pub fn state_mut(&mut self) -> &mut ResidentState {
        &mut self.state
    }

    /// See [`ResidentState::param_uploads`].
    pub fn param_uploads(&self) -> usize {
        self.state.param_uploads()
    }

    /// One buffer-chained SGD step: uploads only the fresh batch (`x`, `y`)
    /// and — when it changed — the `lr` scalar, executes against the
    /// resident buffers, re-binds the output buffers as the new state, and
    /// returns the `(loss, correct)` scalars.
    pub fn step(
        &mut self,
        exe: &Executable,
        meta: &ArtifactMeta,
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<(f32, f32)> {
        let (x_buf, y_buf) = self.upload_batch(meta, xs, ys)?;
        self.refresh_lr(lr)?;
        let n_tr = meta.trainable.len();
        let mut inputs = self.state.step_inputs(meta)?;
        inputs.push(&x_buf);
        inputs.push(&y_buf);
        inputs.push(&self.lr_cache.as_ref().expect("just refreshed").1);
        faults::hit(Seam::Dispatch, &self.fault_scope)?;
        let outs = exe.run_buffers_demux(self.rt, &inputs, 2 * n_tr + 2)?;
        drop(inputs);
        self.state.absorb_step(self.rt, meta, outs)
    }

    /// Upload one batch's `x`/`y` to device buffers.
    fn upload_batch(
        &self,
        meta: &ArtifactMeta,
        xs: &[f32],
        ys: &[i32],
    ) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer)> {
        faults::hit(Seam::BatchUpload, &self.fault_scope)?;
        let x_dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
        let x_buf = self.rt.upload(&xla::Literal::vec1(xs).reshape(&x_dims)?)?;
        let y_buf = self.rt.upload_labels(ys)?;
        Ok((x_buf, y_buf))
    }

    /// Refresh the cached learning-rate buffer when the value changed.
    fn refresh_lr(&mut self, lr: f32) -> Result<()> {
        let stale = match &self.lr_cache {
            Some((v, _)) => *v != lr,
            None => true,
        };
        if stale {
            self.lr_cache = Some((lr, self.rt.upload_scalar(lr)?));
        }
        Ok(())
    }

    /// One epoch over `data`: batches assemble on the [`Prefetcher`] thread
    /// while steps execute, in exactly the order the literal baseline uses
    /// for the same `epoch_seed` (trajectories stay comparable bit-for-bit).
    pub fn run_epoch(
        &mut self,
        exe: &Executable,
        meta: &ArtifactMeta,
        data: &Arc<Dataset>,
        epoch_seed: u64,
        lr: f32,
    ) -> Result<EpochStats> {
        self.run_epoch_sharded(
            exe,
            meta,
            &DataSource::memory(Arc::clone(data)),
            epoch_seed,
            lr,
            Shard::full(),
            &mut |_, _| Ok(()),
        )
    }

    /// [`Engine::run_epoch`] over one shard of the epoch's batch stream,
    /// with `on_step` invoked after every step (receiving the runtime and
    /// the resident state). The data-parallel replicas run their averaging
    /// barrier through the hook ([`replica`]), so the replica step loop
    /// *is* this loop — the f32 metric sums, batch order and early-exit
    /// behavior pinned by the bit-for-bit parity tests cannot drift
    /// between the single-engine and replica paths.
    ///
    /// Data arrives through a [`DataSource`] — resident in memory or
    /// streamed from an object store; the two yield bit-identical batches
    /// (see [`Prefetcher::start_source`]), so the choice never shows up in
    /// the trajectory.
    #[allow(clippy::too_many_arguments)]
    pub fn run_epoch_sharded(
        &mut self,
        exe: &Executable,
        meta: &ArtifactMeta,
        data: &DataSource,
        epoch_seed: u64,
        lr: f32,
        shard: Shard,
        on_step: &mut dyn FnMut(&Runtime, &mut ResidentState) -> Result<()>,
    ) -> Result<EpochStats> {
        let expected_batches = shard.num_batches(data.len() / meta.batch);
        let mut pf = Prefetcher::start_source(data, meta.batch, epoch_seed, shard);
        let mut meter = ThroughputMeter::new(meta.batch);
        // f32 accumulation, in step order — the exact arithmetic the
        // pipelined path's on-device accumulator performs, so the two
        // engines report bit-identical epoch metrics
        let mut loss_sum = 0.0f32;
        let mut correct_sum = 0.0f32;
        let mut samples = 0usize;
        let mut batches = 0usize;
        while let Some((xs, ys)) = pf.next_batch() {
            let t0 = Instant::now();
            let (loss, correct) = self.step(exe, meta, &xs, &ys, lr)?;
            meter.record(t0.elapsed().as_secs_f64());
            loss_sum += loss;
            correct_sum += correct;
            samples += ys.len();
            batches += 1;
            on_step(self.rt, &mut self.state)?;
        }
        if batches != expected_batches {
            bail!(
                "prefetch ended early: {batches} of {expected_batches} batches (epoch seed {epoch_seed})"
            );
        }
        Ok(EpochStats {
            loss: loss_sum as f64 / batches.max(1) as f64,
            train_acc: correct_sum as f64 / samples.max(1) as f64,
            loss_sum,
            correct_sum,
            samples,
            batches,
            meter,
        })
    }

    /// The overlapped epoch: the same batches, executables and update math
    /// as [`Engine::run_epoch`] — bit-identical parameters and metrics —
    /// with the three serial stalls removed:
    ///
    /// 1. **double-buffered uploads** — batch N+1's `x`/`y` upload right
    ///    after step N dispatches, so the host→device transfer rides the
    ///    overlap window instead of serializing before the step;
    /// 2. **split dispatch/fetch** — the step is dispatched asynchronously
    ///    ([`Executable::dispatch_buffers`]) and its outputs demuxed only
    ///    after the next batch is staged;
    /// 3. **on-device metrics** — loss/correct fold into the resident
    ///    [`MetricsAccumulator`]; the per-step 2-scalar host sync becomes
    ///    one fetch per epoch.
    pub fn run_epoch_pipelined(
        &mut self,
        exe: &Executable,
        meta: &ArtifactMeta,
        data: &Arc<Dataset>,
        epoch_seed: u64,
        lr: f32,
    ) -> Result<EpochStats> {
        self.run_epoch_pipelined_sharded(
            exe,
            meta,
            &DataSource::memory(Arc::clone(data)),
            epoch_seed,
            lr,
            Shard::full(),
            &mut |_, _| Ok(()),
        )
    }

    /// [`Engine::run_epoch_pipelined`] over one shard of the epoch's batch
    /// stream, with `on_step` invoked after every absorbed step — the
    /// pipelined twin of [`Engine::run_epoch_sharded`], and what lets the
    /// data-parallel replicas keep the overlapped driver instead of
    /// falling back to the serial loop.
    ///
    /// The hook's composition with the pipeline is safe by construction:
    /// it runs after step N's outputs are demuxed and re-bound
    /// ([`ResidentState::absorb_step_deferred`]) and after the loss/correct
    /// pair folded into the accumulator, so no parameter-carrying work is
    /// in flight — the [`DoubleBuffered`] pair holds at most batch N+1's
    /// `x`/`y`, which is pure data and parameter-independent (the staged
    /// pair is "drained" of parameter dependencies at every step boundary
    /// without discarding the staged batch). A barrier running inside the
    /// hook therefore sees exactly the post-step-N state the serial driver
    /// would hand it, while its leaf downloads overlap the tail of step
    /// N's still-asynchronous device execution; the next dispatch reads
    /// whatever buffers the hook re-bound.
    #[allow(clippy::too_many_arguments)]
    pub fn run_epoch_pipelined_sharded(
        &mut self,
        exe: &Executable,
        meta: &ArtifactMeta,
        data: &DataSource,
        epoch_seed: u64,
        lr: f32,
        shard: Shard,
        on_step: &mut dyn FnMut(&Runtime, &mut ResidentState) -> Result<()>,
    ) -> Result<EpochStats> {
        let expected_batches = shard.num_batches(data.len() / meta.batch);
        if self.metrics.is_none() {
            self.metrics = Some(MetricsAccumulator::create(self.rt, None)?);
        }
        self.refresh_lr(lr)?;
        {
            let metrics = self.metrics.as_mut().expect("just created");
            metrics.reset(self.rt)?;
        }
        let mut pf = Prefetcher::start_source(data, meta.batch, epoch_seed, shard);
        let mut meter = ThroughputMeter::new(meta.batch);
        let mut staged: DoubleBuffered<(xla::PjRtBuffer, xla::PjRtBuffer, usize)> =
            DoubleBuffered::new();
        let pw_t0 = self.tracer.start();
        let first = pf.next_batch();
        self.tracer.end(pw_t0, "train", "prefetch_wait");
        if let Some((xs, ys)) = first {
            let n = ys.len();
            let up_t0 = self.tracer.start();
            let (x, y) = self.upload_batch(meta, &xs, &ys)?;
            self.tracer.end(up_t0, "train", "upload");
            staged.stage((x, y, n))?;
        }
        let n_tr = meta.trainable.len();
        let mut samples = 0usize;
        let mut batches = 0usize;
        while let Some((x_buf, y_buf, n)) = staged.take() {
            let t0 = Instant::now();
            // dispatch step N (non-blocking: PJRT executes asynchronously)
            faults::hit(Seam::Dispatch, &self.fault_scope)?;
            let d_t0 = self.tracer.start();
            let inflight = {
                let mut inputs = self.state.step_inputs(meta)?;
                inputs.push(&x_buf);
                inputs.push(&y_buf);
                inputs.push(&self.lr_cache.as_ref().expect("refreshed above").1);
                exe.dispatch_buffers(&inputs, 2 * n_tr + 2)?
            };
            self.tracer.end(d_t0, "train", "dispatch");
            // overlap window: upload batch N+1 while step N executes
            let pw_t0 = self.tracer.start();
            let next = pf.next_batch();
            self.tracer.end(pw_t0, "train", "prefetch_wait");
            if let Some((xs, ys)) = next {
                let m = ys.len();
                let up_t0 = self.tracer.start();
                let (x, y) = self.upload_batch(meta, &xs, &ys)?;
                self.tracer.end(up_t0, "train", "upload");
                staged.stage((x, y, m))?;
            }
            // demux step N's outputs and re-bind the state; the scalars
            // stay on device and fold into the resident accumulator
            faults::hit(Seam::Fetch, &self.fault_scope)?;
            let f_t0 = self.tracer.start();
            let outs = inflight.fetch(self.rt)?;
            self.tracer.end(f_t0, "train", "fetch");
            let (loss_buf, correct_buf) = self.state.absorb_step_deferred(meta, outs)?;
            self.metrics
                .as_mut()
                .expect("created above")
                .accumulate(&loss_buf, &correct_buf)?;
            meter.record(t0.elapsed().as_secs_f64());
            samples += n;
            batches += 1;
            // step boundary: state is fully re-bound, staged pair holds
            // only data — safe point for the replica averaging barrier
            on_step(self.rt, &mut self.state)?;
        }
        if batches != expected_batches {
            bail!(
                "prefetch ended early: {batches} of {expected_batches} batches (epoch seed {epoch_seed})"
            );
        }
        // the epoch's single metric host sync; the accumulator must have
        // folded exactly one (loss, correct) pair per executed step
        let metrics = self.metrics.as_ref().expect("created above");
        if metrics.steps() != batches {
            bail!(
                "metrics accumulator folded {} steps, epoch ran {batches}",
                metrics.steps()
            );
        }
        let (loss_sum, correct_sum) = metrics.fetch(self.rt)?;
        Ok(EpochStats {
            loss: loss_sum as f64 / batches.max(1) as f64,
            train_acc: correct_sum as f64 / samples.max(1) as f64,
            loss_sum,
            correct_sum,
            samples,
            batches,
            meter,
        })
    }

    /// Accuracy over `data` through an infer executable running directly on
    /// the resident parameter buffers — per batch, only `x` goes up and the
    /// logits come down. Drops the partial final batch (constant AOT batch
    /// shape), like the literal-path evaluation.
    pub fn evaluate(&self, exe: &Executable, meta: &ArtifactMeta, data: &Dataset) -> Result<f64> {
        let params = self
            .state
            .params
            .ordered(meta.trainable.iter().chain(meta.frozen.iter()))?;
        let x_dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
        let batch = meta.batch;
        let mut correct = 0usize;
        let mut total = 0usize;
        for bi in 0..data.len() / batch {
            let (xs, ys) = data.batch(bi * batch, batch);
            let x_buf = self.rt.upload(&xla::Literal::vec1(&xs).reshape(&x_dims)?)?;
            let mut refs = params.clone();
            refs.push(&x_buf);
            let outs = exe.run_buffers(&refs)?;
            let mut lits = Executable::buffer_to_literals(&outs[0])?;
            let logits = literal_to_tensor(&lits.swap_remove(0))?;
            correct += count_correct(logits.data(), logits.shape()[1], &ys);
            total += ys.len();
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Download the full training state — the semantically-required host
    /// syncs (checkpointing, returning final parameters) go through here.
    pub fn sync(&self) -> Result<(Params, Params)> {
        self.state.sync()
    }
}
