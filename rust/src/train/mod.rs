//! `lrta::train` — the device-resident training engine.
//!
//! The paper's headline number is *training* throughput (+60% for rank
//! optimization + sequential freezing combined), and the literal-based
//! step loop ([`run_train_step`](crate::coordinator::run_train_step))
//! gives most of that back by round-tripping every parameter and momentum
//! tensor through host literals on every step. This module is the training
//! counterpart of the serving layer's residency work:
//!
//! ```text
//!   upload params+momenta once ──▶ [ResidentState]   (named device buffers)
//!                                        │
//!        ┌── epoch ──────────────────────▼──────────────────────────────┐
//!        │ [Prefetcher] assemble batch N+1 ║ step N executes on device  │
//!        │     x,y,lr upload (data only) ──▶ [train exe] run_buffers    │
//!        │     new params / momenta ◀────── demuxed output buffers      │
//!        │     (re-bound in place — step N+1 reads them directly)       │
//!        └───────────────────────────────────────────────────────────────┘
//!                                        │
//!             epoch boundary: Algorithm 2 swaps pattern a↔b —
//!             the *same* buffers re-bind to the new executable's
//!             slot layout (trainable↔frozen roles swap; nothing is
//!             downloaded or re-uploaded)
//!                                        │
//!             host sync only where semantics demand it: per-step
//!             loss/correct scalars, per-epoch eval (which itself runs
//!             on the resident buffers), checkpoint/final-state download
//! ```
//!
//! [`Engine`] owns the state and the step/epoch/eval primitives;
//! [`crate::coordinator::Trainer`] drives it (freeze schedule, records,
//! learning-rate schedule) and falls back to the literal baseline when
//! `TrainConfig::resident` is off (`lrta train --no-resident`), which is
//! what `bench_train_resident` compares against.

pub mod prefetch;
pub mod resident;

pub use prefetch::Prefetcher;
pub use resident::{ResidentParams, ResidentState};

use crate::checkpoint::Params;
use crate::data::Dataset;
use crate::metrics::ThroughputMeter;
use crate::runtime::{literal_to_tensor, ArtifactMeta, Executable, Runtime};
use crate::util::stats::count_correct;
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Instant;

/// Aggregates of one training epoch through the resident engine.
pub struct EpochStats {
    /// Mean per-batch training loss.
    pub loss: f64,
    /// Training accuracy over the epoch.
    pub train_acc: f64,
    pub samples: usize,
    pub batches: usize,
    /// Per-step wall times (batch-upload + execute + scalar sync).
    pub meter: ThroughputMeter,
}

/// The device-resident training engine: buffer-to-buffer step chaining
/// with freeze-pattern rebinding. See the module docs for the data flow.
pub struct Engine<'rt> {
    rt: &'rt Runtime,
    state: ResidentState,
    /// The learning rate is an executable input; its device buffer is
    /// cached per distinct value (it changes once per epoch at most).
    lr_cache: Option<(f32, xla::PjRtBuffer)>,
}

impl<'rt> Engine<'rt> {
    /// Upload the full training state (all parameters, all momenta) once.
    pub fn upload(rt: &'rt Runtime, params: &Params, momenta: &Params) -> Result<Engine<'rt>> {
        Ok(Engine { rt, state: ResidentState::upload(rt, params, momenta)?, lr_cache: None })
    }

    pub fn state(&self) -> &ResidentState {
        &self.state
    }

    /// See [`ResidentState::param_uploads`].
    pub fn param_uploads(&self) -> usize {
        self.state.param_uploads()
    }

    /// One buffer-chained SGD step: uploads only the fresh batch (`x`, `y`)
    /// and — when it changed — the `lr` scalar, executes against the
    /// resident buffers, re-binds the output buffers as the new state, and
    /// returns the `(loss, correct)` scalars.
    pub fn step(
        &mut self,
        exe: &Executable,
        meta: &ArtifactMeta,
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<(f32, f32)> {
        let x_dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
        let x_buf = self.rt.upload(&xla::Literal::vec1(xs).reshape(&x_dims)?)?;
        let y_buf = self.rt.upload_labels(ys)?;
        let lr_stale = match &self.lr_cache {
            Some((v, _)) => *v != lr,
            None => true,
        };
        if lr_stale {
            self.lr_cache = Some((lr, self.rt.upload_scalar(lr)?));
        }
        let n_tr = meta.trainable.len();
        let mut inputs = self.state.step_inputs(meta)?;
        inputs.push(&x_buf);
        inputs.push(&y_buf);
        inputs.push(&self.lr_cache.as_ref().expect("just refreshed").1);
        let outs = exe.run_buffers_demux(self.rt, &inputs, 2 * n_tr + 2)?;
        drop(inputs);
        self.state.absorb_step(meta, outs)
    }

    /// One epoch over `data`: batches assemble on the [`Prefetcher`] thread
    /// while steps execute, in exactly the order the literal baseline uses
    /// for the same `epoch_seed` (trajectories stay comparable bit-for-bit).
    pub fn run_epoch(
        &mut self,
        exe: &Executable,
        meta: &ArtifactMeta,
        data: &Arc<Dataset>,
        epoch_seed: u64,
        lr: f32,
    ) -> Result<EpochStats> {
        let expected_batches = data.len() / meta.batch;
        let mut pf = Prefetcher::start(Arc::clone(data), meta.batch, epoch_seed);
        let mut meter = ThroughputMeter::new(meta.batch);
        let mut loss_sum = 0.0f64;
        let mut correct_sum = 0.0f64;
        let mut samples = 0usize;
        let mut batches = 0usize;
        while let Some((xs, ys)) = pf.next_batch() {
            let t0 = Instant::now();
            let (loss, correct) = self.step(exe, meta, &xs, &ys, lr)?;
            meter.record(t0.elapsed().as_secs_f64());
            loss_sum += loss as f64;
            correct_sum += correct as f64;
            samples += ys.len();
            batches += 1;
        }
        if batches != expected_batches {
            bail!(
                "prefetch ended early: {batches} of {expected_batches} batches (epoch seed {epoch_seed})"
            );
        }
        Ok(EpochStats {
            loss: loss_sum / batches.max(1) as f64,
            train_acc: correct_sum / samples.max(1) as f64,
            samples,
            batches,
            meter,
        })
    }

    /// Accuracy over `data` through an infer executable running directly on
    /// the resident parameter buffers — per batch, only `x` goes up and the
    /// logits come down. Drops the partial final batch (constant AOT batch
    /// shape), like the literal-path evaluation.
    pub fn evaluate(&self, exe: &Executable, meta: &ArtifactMeta, data: &Dataset) -> Result<f64> {
        let params = self
            .state
            .params
            .ordered(meta.trainable.iter().chain(meta.frozen.iter()))?;
        let x_dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
        let batch = meta.batch;
        let mut correct = 0usize;
        let mut total = 0usize;
        for bi in 0..data.len() / batch {
            let (xs, ys) = data.batch(bi * batch, batch);
            let x_buf = self.rt.upload(&xla::Literal::vec1(&xs).reshape(&x_dims)?)?;
            let mut refs = params.clone();
            refs.push(&x_buf);
            let outs = exe.run_buffers(&refs)?;
            let mut lits = Executable::buffer_to_literals(&outs[0])?;
            let logits = literal_to_tensor(&lits.swap_remove(0))?;
            correct += count_correct(logits.data(), logits.shape()[1], &ys);
            total += ys.len();
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Download the full training state — the semantically-required host
    /// syncs (checkpointing, returning final parameters) go through here.
    pub fn sync(&self) -> Result<(Params, Params)> {
        self.state.sync()
    }
}
