//! [`LocalFs`] — keys are files under a root directory.
//!
//! The filesystem backend keeps today's on-disk layout: key `a/b/c` is the
//! file `<root>/a/b/c`, so a checkpoint written through [`LocalFs`] is the
//! same file `checkpoint::save` used to write (byte-identical — pinned in
//! the conformance and checkpoint suites). What it adds over raw
//! `std::fs` calls is the object-store contract:
//!
//! - **atomic put-by-rename** — every put writes `<root>/.tmp/<unique>`
//!   and renames it over the destination, so a concurrent reader sees the
//!   old object or the new one, never a torn write;
//! - **typed missing-key errors** — `ENOENT` maps to
//!   [`super::NotFound`];
//! - **namespaced listing** — [`Storage::list`] walks the tree and
//!   returns `/`-joined keys (internal `.tmp` staging excluded).

use super::{NotFound, Storage, StoreCore};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Name of the staging directory for atomic puts (excluded from listings;
/// `.`-prefixed, which [`super::validate_key`] keeps out of key space).
const TMP_DIR: &str = ".tmp";

/// Filesystem-rooted object store. See the module docs for the contract.
pub struct LocalFs {
    root: PathBuf,
    core: StoreCore,
    /// Per-store monotonic suffix keeping concurrent staged writes apart.
    tmp_seq: AtomicU64,
}

impl LocalFs {
    /// Open (creating if needed) an object store rooted at `root`.
    pub fn open(root: PathBuf) -> Result<LocalFs> {
        std::fs::create_dir_all(&root)
            .with_context(|| format!("create storage root {}", root.display()))?;
        Ok(LocalFs { root, core: StoreCore::new(), tmp_seq: AtomicU64::new(0) })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The file behind `key` (already validated by the trait wrappers).
    fn path_of(&self, key: &str) -> PathBuf {
        self.root.join(key)
    }

    /// Stage into `.tmp/` then rename over the destination.
    fn commit_tmp(&self, key: &str, tmp: &Path) -> Result<()> {
        let dst = self.path_of(key);
        if let Some(dir) = dst.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("create storage dir {}", dir.display()))?;
        }
        std::fs::rename(tmp, &dst)
            .with_context(|| format!("commit {} -> {}", tmp.display(), dst.display()))
    }

    fn tmp_path(&self) -> Result<PathBuf> {
        let dir = self.root.join(TMP_DIR);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create staging dir {}", dir.display()))?;
        let seq = self.tmp_seq.fetch_add(1, Ordering::Relaxed);
        Ok(dir.join(format!("put-{}-{}", std::process::id(), seq)))
    }

    fn walk(&self, dir: &Path, rel: &mut Vec<String>, out: &mut Vec<String>) -> Result<()> {
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("list storage dir {}", dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if rel.is_empty() && name == TMP_DIR {
                continue;
            }
            let ty = entry.file_type()?;
            if ty.is_dir() {
                rel.push(name);
                self.walk(&entry.path(), rel, out)?;
                rel.pop();
            } else if ty.is_file() {
                let mut key = rel.join("/");
                if !key.is_empty() {
                    key.push('/');
                }
                key.push_str(&name);
                out.push(key);
            }
        }
        Ok(())
    }
}

impl Storage for LocalFs {
    fn backend(&self) -> &'static str {
        "localfs"
    }

    fn core(&self) -> &StoreCore {
        &self.core
    }

    fn get_raw(&self, key: &str) -> Result<Vec<u8>> {
        let path = self.path_of(key);
        match std::fs::read(&path) {
            Ok(bytes) => Ok(bytes),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                Err(NotFound { key: key.to_string() }.into())
            }
            Err(e) => Err(e).with_context(|| format!("read {}", path.display())),
        }
    }

    fn put_raw(&self, key: &str, data: &[u8]) -> Result<()> {
        let tmp = self.tmp_path()?;
        std::fs::write(&tmp, data).with_context(|| format!("stage {}", tmp.display()))?;
        self.commit_tmp(key, &tmp)
    }

    fn put_streaming_raw(&self, key: &str, reader: &mut dyn Read) -> Result<u64> {
        let tmp = self.tmp_path()?;
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(&tmp).with_context(|| format!("stage {}", tmp.display()))?,
        );
        let n = std::io::copy(reader, &mut f)
            .with_context(|| format!("stream into {}", tmp.display()))?;
        f.flush().with_context(|| format!("flush {}", tmp.display()))?;
        drop(f);
        self.commit_tmp(key, &tmp)?;
        Ok(n)
    }

    fn list_raw(&self, prefix: &str) -> Result<Vec<String>> {
        let mut out = Vec::new();
        let mut rel = Vec::new();
        self.walk(&self.root, &mut rel, &mut out)?;
        out.retain(|k| k.starts_with(prefix));
        out.sort();
        Ok(out)
    }

    fn delete_raw(&self, key: &str) -> Result<()> {
        let path = self.path_of(key);
        match std::fs::remove_file(&path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()), // idempotent
            Err(e) => Err(e).with_context(|| format!("delete {}", path.display())),
        }
    }

    fn exists_raw(&self, key: &str) -> Result<bool> {
        Ok(self.path_of(key).is_file())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(name: &str) -> LocalFs {
        let dir = std::env::temp_dir().join("lrta_storage_local_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        LocalFs::open(dir).unwrap()
    }

    #[test]
    fn keys_map_to_files_under_root() {
        let s = tmp_store("layout");
        s.put("ckpts/epoch_000.bin", b"abc").unwrap();
        assert_eq!(std::fs::read(s.root().join("ckpts/epoch_000.bin")).unwrap(), b"abc");
    }

    #[test]
    fn listing_skips_staging_dir() {
        let s = tmp_store("staging");
        s.put("a", b"1").unwrap();
        // leave a stale staged file behind (simulated crash mid-put)
        std::fs::write(s.root().join(TMP_DIR).join("stale"), b"x").unwrap();
        assert_eq!(s.list("").unwrap(), vec!["a".to_string()]);
    }

    #[test]
    fn streaming_put_roundtrips() {
        let s = tmp_store("stream");
        let data = vec![7u8; 100_000];
        let n = s.put_streaming("big", &mut &data[..]).unwrap();
        assert_eq!(n, data.len() as u64);
        assert_eq!(s.get("big").unwrap(), data);
    }

    #[test]
    fn unwritable_root_surfaces_on_open() {
        let blocker = std::env::temp_dir().join("lrta_storage_local_blocker");
        let _ = std::fs::remove_dir_all(&blocker);
        let _ = std::fs::remove_file(&blocker);
        std::fs::write(&blocker, "file").unwrap();
        assert!(LocalFs::open(blocker.join("sub")).is_err());
        let _ = std::fs::remove_file(&blocker);
    }
}
