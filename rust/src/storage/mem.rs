//! [`MemObject`] — an in-process object store with remote-object
//! semantics.
//!
//! The point of this backend is not speed (it is a `BTreeMap` behind a
//! mutex) but *discipline*: it behaves like a remote bucket so every
//! streaming path exercises the semantics an S3/GCS backend would impose,
//! without the network:
//!
//! - **whole-object operations** — a put buffers the entire object before
//!   a single insert under the lock (streaming included), so readers never
//!   observe a partially-written object; a get returns a complete
//!   committed object or [`super::NotFound`];
//! - **latency injection** — [`MemObject::set_latency`] adds a fixed
//!   per-get/put sleep, turning any unit test into a slow-object-store
//!   test (the streamed-prefetch window sizing is tuned against this and
//!   the `storage_get:stall` fault seam);
//! - **shared by name** — [`super::open`] hands out process-global named
//!   instances (`mem:NAME`), emulating one bucket shared by a trainer and
//!   a server in the same process.

use super::{NotFound, Storage, StoreCore};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::Read;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// In-memory object store. See the module docs for the emulated contract.
#[derive(Default)]
pub struct MemObject {
    objects: Mutex<BTreeMap<String, Arc<Vec<u8>>>>,
    /// Injected per-get/put latency (zero by default).
    latency: Mutex<Duration>,
    core: StoreCore,
}

impl MemObject {
    pub fn new() -> MemObject {
        MemObject::default()
    }

    /// Builder form of [`MemObject::set_latency`].
    pub fn with_latency(latency: Duration) -> MemObject {
        let s = MemObject::new();
        s.set_latency(latency);
        s
    }

    /// Every subsequent get/put sleeps `latency` first — the knob that
    /// makes "remote" object-store slowness reproducible in-process.
    pub fn set_latency(&self, latency: Duration) {
        *self.latency.lock().expect("mem latency lock") = latency;
    }

    /// Number of stored objects.
    pub fn len(&self) -> usize {
        self.objects.lock().expect("mem objects lock").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total stored bytes across all objects.
    pub fn stored_bytes(&self) -> u64 {
        self.objects
            .lock()
            .expect("mem objects lock")
            .values()
            .map(|v| v.len() as u64)
            .sum()
    }

    /// Drop every object (latency/metrics state untouched) — lets tests
    /// reuse a process-global named store with a clean namespace.
    pub fn clear(&self) {
        self.objects.lock().expect("mem objects lock").clear();
    }

    fn simulate_latency(&self) {
        let d = *self.latency.lock().expect("mem latency lock");
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }
}

impl Storage for MemObject {
    fn backend(&self) -> &'static str {
        "mem"
    }

    fn core(&self) -> &StoreCore {
        &self.core
    }

    fn get_raw(&self, key: &str) -> Result<Vec<u8>> {
        self.simulate_latency();
        let objects = self.objects.lock().expect("mem objects lock");
        match objects.get(key) {
            Some(obj) => Ok(obj.as_ref().clone()),
            None => Err(NotFound { key: key.to_string() }.into()),
        }
    }

    fn put_raw(&self, key: &str, data: &[u8]) -> Result<()> {
        self.simulate_latency();
        // buffer fully *before* taking the lock: the insert is the single
        // atomic commit point, like a remote PUT completing
        let obj = Arc::new(data.to_vec());
        self.objects.lock().expect("mem objects lock").insert(key.to_string(), obj);
        Ok(())
    }

    fn put_streaming_raw(&self, key: &str, reader: &mut dyn Read) -> Result<u64> {
        self.simulate_latency();
        let mut buf = Vec::new();
        reader
            .read_to_end(&mut buf)
            .with_context(|| format!("buffer streaming put of '{key}'"))?;
        let n = buf.len() as u64;
        self.objects.lock().expect("mem objects lock").insert(key.to_string(), Arc::new(buf));
        Ok(n)
    }

    fn list_raw(&self, prefix: &str) -> Result<Vec<String>> {
        let objects = self.objects.lock().expect("mem objects lock");
        Ok(objects.keys().filter(|k| k.starts_with(prefix)).cloned().collect())
    }

    fn delete_raw(&self, key: &str) -> Result<()> {
        self.objects.lock().expect("mem objects lock").remove(key);
        Ok(())
    }

    fn exists_raw(&self, key: &str) -> Result<bool> {
        Ok(self.objects.lock().expect("mem objects lock").contains_key(key))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn listing_is_sorted_by_key() {
        let s = MemObject::new();
        for k in ["b/2", "a/1", "b/1", "c"] {
            s.put(k, b"x").unwrap();
        }
        assert_eq!(s.list("b/").unwrap(), vec!["b/1".to_string(), "b/2".to_string()]);
        assert_eq!(s.list("").unwrap().len(), 4);
    }

    #[test]
    fn latency_injection_slows_gets() {
        let s = MemObject::with_latency(Duration::from_millis(25));
        s.set_latency(Duration::ZERO);
        s.put("k", b"v").unwrap();
        s.set_latency(Duration::from_millis(25));
        let t0 = Instant::now();
        let _ = s.get("k").unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn overwrite_replaces_whole_object() {
        let s = MemObject::new();
        s.put("k", b"first version, long").unwrap();
        s.put("k", b"v2").unwrap();
        assert_eq!(s.get("k").unwrap(), b"v2");
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn stored_bytes_tracks_contents() {
        let s = MemObject::new();
        s.put("a", &[0u8; 10]).unwrap();
        s.put("b", &[0u8; 32]).unwrap();
        assert_eq!(s.stored_bytes(), 42);
        s.delete("a").unwrap();
        assert_eq!(s.stored_bytes(), 32);
        s.clear();
        assert!(s.is_empty());
    }
}
