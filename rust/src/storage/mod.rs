//! `lrta::storage` — the pluggable object-store boundary every byte of
//! model state and training data crosses.
//!
//! The rest of the system used to assume a local, synchronous filesystem:
//! `checkpoint::{save,load}` wrote files in place, `data::Dataset` lived
//! fully in RAM, and `serve`'s warm swap could only read checkpoints the
//! process could already `open(2)`. This module traits that boundary:
//!
//! - [`Storage`] — get/put/put_streaming/list/delete/exists over
//!   namespaced `a/b/c` keys. Backends implement only the raw I/O
//!   (`*_raw` methods); the provided trait methods layer the repo's
//!   cross-cutting invariants on *every* backend uniformly:
//!   - **exact accounting** — op and byte counters ([`StorageMetrics`])
//!     registered under the `storage` subsystem with a `{backend}` label,
//!     plus `storage/storage_get|storage_put` lifecycle spans;
//!   - **fault seams** — [`crate::faults::Seam::StorageGet`] /
//!     [`crate::faults::Seam::StoragePut`] fire inside every read/write,
//!     scoped by the backend label (`storage_put@mem:error`), closing the
//!     checkpoint-side-thread seam follow-on from the fault-injection PR;
//!   - **key hygiene** — keys are validated once, centrally
//!     ([`validate_key`]).
//! - [`LocalFs`] — keys are files under a root directory; puts are
//!   atomic (temp file + rename), reads map `ENOENT` to the typed
//!   [`NotFound`] error shape.
//! - [`MemObject`] — an in-process object store emulating remote-object
//!   semantics (whole-object atomic puts, no partial reads, an injectable
//!   per-op latency) so streaming paths are testable today and an S3/GCS
//!   backend is a third impl later, not a redesign.
//! - [`chunk::ChunkStore`] — content-addressed chunks + manifests on top
//!   of any backend, so large params/data dedupe across epochs and rank
//!   variants.
//!
//! [`open`] maps a CLI URI to a backend: `mem:` / `mem:NAME` return a
//! process-global *named* [`MemObject`] (so `lrta train --store mem:` and
//! a later in-process `serve` swap read the same store), anything else is
//! a [`LocalFs`] root directory.
//!
//! Consumers: `checkpoint::{save_to,load_from}` (codec over bytes),
//! `train::CheckpointWriter` (async epoch uploads via `put_streaming`),
//! `data::stream::StreamingProvider` (chunked corpus → prefetcher), and
//! `serve::Server::swap_variant_from_store`.

pub mod chunk;
pub mod local;
pub mod mem;

pub use chunk::{ChunkStore, PutStats};
pub use local::LocalFs;
pub use mem::MemObject;

use crate::faults::{self, Seam};
use crate::obs::{Counter, Registry, Tracer};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::io::Read;
use std::sync::{Arc, Mutex, OnceLock, RwLock};

/// Typed "no such key" error, preserved through `anyhow` chains so callers
/// (and the backend conformance suite) can distinguish a missing object
/// from an I/O failure: `storage::is_not_found(&err)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NotFound {
    pub key: String,
}

impl std::fmt::Display for NotFound {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "storage key not found: {}", self.key)
    }
}

impl std::error::Error for NotFound {}

/// Whether `err`'s chain bottoms out in a [`NotFound`] — the one storage
/// error callers branch on (e.g. chunk dedupe probes, cache misses).
pub fn is_not_found(err: &anyhow::Error) -> bool {
    err.chain().any(|c| c.downcast_ref::<NotFound>().is_some())
}

/// Exact per-backend op/byte accounting. The handles are shared atomics
/// ([`Counter`]): hot paths increment them lock-free and
/// [`StorageMetrics::register`] indexes the *same* atomics into an obs
/// [`Registry`], so exports match the live values bit-for-bit.
#[derive(Clone, Debug, Default)]
pub struct StorageMetrics {
    pub get_ops: Counter,
    pub get_bytes: Counter,
    pub put_ops: Counter,
    pub put_bytes: Counter,
    pub list_ops: Counter,
    pub delete_ops: Counter,
}

impl StorageMetrics {
    /// Register every counter under `storage/<name>{backend=…}`.
    pub fn register(&self, registry: &Registry, backend: &str) -> Result<()> {
        let labels = [("backend", backend)];
        registry.register_counter("storage", "get_ops", &labels, &self.get_ops)?;
        registry.register_counter("storage", "get_bytes", &labels, &self.get_bytes)?;
        registry.register_counter("storage", "put_ops", &labels, &self.put_ops)?;
        registry.register_counter("storage", "put_bytes", &labels, &self.put_bytes)?;
        registry.register_counter("storage", "list_ops", &labels, &self.list_ops)?;
        registry.register_counter("storage", "delete_ops", &labels, &self.delete_ops)?;
        Ok(())
    }
}

/// The instrumentation state every backend embeds: shared metric handles
/// plus a swappable span recorder. Backends expose it via
/// [`Storage::core`]; the provided trait methods do the rest.
#[derive(Debug, Default)]
pub struct StoreCore {
    metrics: StorageMetrics,
    tracer: RwLock<Tracer>,
}

impl StoreCore {
    pub fn new() -> StoreCore {
        StoreCore::default()
    }

    fn tracer(&self) -> Tracer {
        self.tracer.read().expect("storage tracer lock").clone()
    }
}

/// Reject keys that could escape the namespace or collide with backend
/// internals: empty keys, empty / `.` / `..` segments, leading `/`.
pub fn validate_key(key: &str) -> Result<()> {
    if key.is_empty() {
        bail!("storage key must be non-empty");
    }
    for seg in key.split('/') {
        if seg.is_empty() {
            bail!("storage key '{key}': empty path segment");
        }
        if seg == "." || seg == ".." {
            bail!("storage key '{key}': '.'/'..' segments are not allowed");
        }
    }
    Ok(())
}

/// The object-store boundary. Implementations provide the `*_raw` I/O;
/// callers use the provided (instrumented) methods — [`Storage::get`],
/// [`Storage::put`], [`Storage::put_streaming`], [`Storage::list`],
/// [`Storage::delete`], [`Storage::exists`] — which add key validation,
/// fault seams, op/byte counters, and `storage_get`/`storage_put` spans
/// identically over every backend.
pub trait Storage: Send + Sync {
    /// Backend label: metric `{backend=…}` value and fault-seam scope.
    fn backend(&self) -> &'static str;

    /// The shared instrumentation state (metrics + tracer).
    fn core(&self) -> &StoreCore;

    /// Fetch the whole object at `key` ([`NotFound`] if absent). No
    /// partial reads: the returned bytes are a complete, committed object.
    fn get_raw(&self, key: &str) -> Result<Vec<u8>>;

    /// Store `data` at `key`, atomically replacing any existing object —
    /// concurrent readers see the old bytes or the new, never a mix.
    fn put_raw(&self, key: &str, data: &[u8]) -> Result<()>;

    /// Stream `reader` to `key` with the same atomic-commit contract;
    /// returns the byte count written.
    fn put_streaming_raw(&self, key: &str, reader: &mut dyn Read) -> Result<u64>;

    /// Keys starting with `prefix` (plain string prefix over the `a/b/c`
    /// namespace), sorted.
    fn list_raw(&self, prefix: &str) -> Result<Vec<String>>;

    /// Remove `key`. Idempotent: deleting an absent key succeeds.
    fn delete_raw(&self, key: &str) -> Result<()>;

    /// Whether `key` holds an object (cheaper than a full `get`).
    fn exists_raw(&self, key: &str) -> Result<bool>;

    // ---- instrumented entry points (what callers use) -------------------

    /// [`Storage::get_raw`] + seam/span/accounting.
    fn get(&self, key: &str) -> Result<Vec<u8>> {
        validate_key(key)?;
        let core = self.core();
        let span = core.tracer().start();
        faults::hit(Seam::StorageGet, self.backend())?;
        let out = self.get_raw(key);
        if let Ok(bytes) = &out {
            core.metrics.get_ops.inc();
            core.metrics.get_bytes.add(bytes.len() as u64);
        }
        core.tracer().end(span, "storage", "storage_get");
        out
    }

    /// [`Storage::put_raw`] + seam/span/accounting.
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        validate_key(key)?;
        let core = self.core();
        let span = core.tracer().start();
        faults::hit(Seam::StoragePut, self.backend())?;
        let out = self.put_raw(key, data);
        if out.is_ok() {
            core.metrics.put_ops.inc();
            core.metrics.put_bytes.add(data.len() as u64);
        }
        core.tracer().end(span, "storage", "storage_put");
        out
    }

    /// [`Storage::put_streaming_raw`] + seam/span/accounting.
    fn put_streaming(&self, key: &str, reader: &mut dyn Read) -> Result<u64> {
        validate_key(key)?;
        let core = self.core();
        let span = core.tracer().start();
        faults::hit(Seam::StoragePut, self.backend())?;
        let out = self.put_streaming_raw(key, reader);
        if let Ok(n) = &out {
            core.metrics.put_ops.inc();
            core.metrics.put_bytes.add(*n);
        }
        core.tracer().end(span, "storage", "storage_put");
        out
    }

    /// [`Storage::list_raw`] + accounting. An empty prefix lists all keys.
    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let out = self.list_raw(prefix);
        if out.is_ok() {
            self.core().metrics.list_ops.inc();
        }
        out
    }

    /// [`Storage::delete_raw`] + accounting.
    fn delete(&self, key: &str) -> Result<()> {
        validate_key(key)?;
        let out = self.delete_raw(key);
        if out.is_ok() {
            self.core().metrics.delete_ops.inc();
        }
        out
    }

    /// [`Storage::exists_raw`] + the `storage_get` seam (a dedupe probe is
    /// a read, and a stalled remote HEAD stalls it like a GET).
    fn exists(&self, key: &str) -> Result<bool> {
        validate_key(key)?;
        faults::hit(Seam::StorageGet, self.backend())?;
        self.exists_raw(key)
    }

    /// Live op/byte counters (shared atomics).
    fn metrics(&self) -> &StorageMetrics {
        &self.core().metrics
    }

    /// Index this backend's counters into `registry` under
    /// `storage/*{backend=…}`.
    fn register_metrics(&self, registry: &Registry) -> Result<()> {
        self.core().metrics.register(registry, self.backend())
    }

    /// Install a span recorder: every get/put records a
    /// `storage/storage_get|storage_put` lifecycle span.
    fn set_tracer(&self, tracer: Tracer) {
        *self.core().tracer.write().expect("storage tracer lock") = tracer;
    }
}

/// Process-global registry of named [`MemObject`] stores, so every
/// `open("mem:NAME")` in one process shares the same objects — what lets a
/// `--store mem:` training run hand its checkpoints to an in-process
/// serve swap (the CI smoke), mirroring how independent processes would
/// share one remote bucket.
fn mem_registry() -> &'static Mutex<HashMap<String, Arc<MemObject>>> {
    static MEMS: OnceLock<Mutex<HashMap<String, Arc<MemObject>>>> = OnceLock::new();
    MEMS.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Resolve a CLI storage URI to a backend:
///
/// - `mem:` / `mem:NAME` — the process-global shared [`MemObject`] named
///   `NAME` (default name for bare `mem:`), created on first open;
/// - anything else — a [`LocalFs`] rooted at that directory (created if
///   missing).
pub fn open(uri: &str) -> Result<Arc<dyn Storage>> {
    let uri = uri.trim();
    if uri.is_empty() {
        bail!("storage URI must be non-empty (DIR or mem:[NAME])");
    }
    if let Some(name) = uri.strip_prefix("mem:") {
        let name = if name.is_empty() { "default" } else { name };
        let mut mems = mem_registry().lock().expect("mem store registry lock");
        let store = mems
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(MemObject::new()))
            .clone();
        return Ok(store);
    }
    Ok(Arc::new(LocalFs::open(std::path::PathBuf::from(uri))?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_validation() {
        for ok in ["a", "a/b", "ckpts/epoch_000.bin", "chunks/00ff"] {
            assert!(validate_key(ok).is_ok(), "{ok}");
        }
        for bad in ["", "/a", "a//b", "a/", "../x", "a/./b", "a/.."] {
            assert!(validate_key(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn not_found_survives_anyhow_context() {
        use anyhow::Context;
        let base: anyhow::Error = NotFound { key: "k".into() }.into();
        let wrapped = base.context("load checkpoint ckpts/epoch_000.bin");
        assert!(is_not_found(&wrapped));
        assert!(!is_not_found(&anyhow::anyhow!("disk on fire")));
    }

    #[test]
    fn open_mem_uris_share_by_name() {
        let a = open("mem:open_test_a").unwrap();
        let b = open("mem:open_test_a").unwrap();
        let c = open("mem:open_test_c").unwrap();
        a.put("k", b"v").unwrap();
        assert_eq!(b.get("k").unwrap(), b"v");
        assert!(is_not_found(&c.get("k").unwrap_err()));
    }

    #[test]
    fn open_bare_mem_is_the_default_name() {
        let a = open("mem:").unwrap();
        let b = open("mem:default").unwrap();
        a.put("bare", b"x").unwrap();
        assert_eq!(b.get("bare").unwrap(), b"x");
    }

    #[test]
    fn open_path_is_localfs() {
        let dir = std::env::temp_dir().join("lrta_storage_open_localfs");
        let _ = std::fs::remove_dir_all(&dir);
        let s = open(dir.to_str().unwrap()).unwrap();
        assert_eq!(s.backend(), "localfs");
        s.put("a/b", b"bytes").unwrap();
        assert!(dir.join("a/b").is_file());
    }

    #[test]
    fn accounting_is_exact_and_registered() {
        let s = MemObject::new();
        s.put("a", &[0u8; 10]).unwrap();
        s.put("b/c", &[0u8; 5]).unwrap();
        let _ = s.get("a").unwrap();
        let _ = s.get("a").unwrap();
        let _ = s.list("").unwrap();
        s.delete("a").unwrap();
        assert_eq!(s.metrics().put_ops.get(), 2);
        assert_eq!(s.metrics().put_bytes.get(), 15);
        assert_eq!(s.metrics().get_ops.get(), 2);
        assert_eq!(s.metrics().get_bytes.get(), 20);
        assert_eq!(s.metrics().list_ops.get(), 1);
        assert_eq!(s.metrics().delete_ops.get(), 1);
        // failed ops do not count
        assert!(s.get("missing").is_err());
        assert_eq!(s.metrics().get_ops.get(), 2);
        // the registry reads the same atomics
        let reg = Registry::new();
        s.register_metrics(&reg).unwrap();
        assert_eq!(reg.scalar("storage", "put_bytes", &[("backend", "mem")]), Some(15));
        assert_eq!(reg.scalar("storage", "get_ops", &[("backend", "mem")]), Some(2));
    }

    #[test]
    fn get_put_record_spans() {
        let s = MemObject::new();
        let tracer = Tracer::enabled();
        s.set_tracer(tracer.clone());
        s.put("k", b"v").unwrap();
        let _ = s.get("k").unwrap();
        let names: Vec<&str> = tracer.events().iter().map(|e| e.name).collect();
        assert!(names.contains(&"storage_put"), "{names:?}");
        assert!(names.contains(&"storage_get"), "{names:?}");
    }
}
