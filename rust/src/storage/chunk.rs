//! Content-addressed chunk store: large blobs split into fixed-size
//! chunks keyed by their content hash, reassembled through a JSON
//! manifest.
//!
//! Why content addressing: the paper's workflow (decompose → rank-sweep →
//! retrain → serve many rank variants) multiplies near-identical large
//! blobs — epoch checkpoints that share frozen tensors, rank variants of
//! one corpus. Hashing each chunk and skipping the put when the key
//! already exists makes that redundancy free at the storage layer, with
//! no coordination: two writers racing on the same chunk write the same
//! bytes.
//!
//! Layout on the underlying [`Storage`]:
//!
//! ```text
//!   chunks/<32-hex fnv1a-128 of the chunk bytes>   one chunk each
//!   <manifest_key>                                 JSON manifest:
//!     {"blob_len": N, "chunk_size": C,
//!      "chunks": [{"key": "chunks/…", "len": L}, …]}
//! ```
//!
//! The hash is an inline FNV-1a (128-bit) — dependency-free and plenty
//! for *integrity and dedupe of trusted data*; it is not
//! collision-resistant against an adversary, which matches the threat
//! model of a training artifact store (same stance as the repo's other
//! hand-rolled primitives; swap in a cryptographic hash alongside a real
//! S3/GCS backend if the trust boundary moves).

use super::Storage;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Key prefix shared by every content-addressed chunk.
pub const CHUNK_PREFIX: &str = "chunks/";

/// Default chunk size (bytes) — small enough that one epoch's changed
/// tensors touch few chunks, large enough that manifests stay short.
pub const DEFAULT_CHUNK_SIZE: usize = 256 * 1024;

/// Exact accounting of one [`ChunkStore::put_blob`]: how much the
/// content-addressing actually saved.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PutStats {
    /// Chunks the blob splits into.
    pub chunks_total: usize,
    /// Chunks actually uploaded (the rest already existed).
    pub chunks_written: usize,
    /// Blob size in bytes.
    pub bytes_total: u64,
    /// Bytes actually uploaded.
    pub bytes_written: u64,
    /// Bytes skipped because their chunk already existed.
    pub bytes_deduped: u64,
}

/// Content-addressed chunking over any [`Storage`] backend.
#[derive(Clone)]
pub struct ChunkStore {
    store: Arc<dyn Storage>,
    chunk_size: usize,
}

impl ChunkStore {
    /// Chunk store with the [`DEFAULT_CHUNK_SIZE`].
    pub fn new(store: Arc<dyn Storage>) -> ChunkStore {
        Self::with_chunk_size(store, DEFAULT_CHUNK_SIZE)
    }

    /// # Panics
    /// If `chunk_size` is zero.
    pub fn with_chunk_size(store: Arc<dyn Storage>, chunk_size: usize) -> ChunkStore {
        assert!(chunk_size > 0, "chunk size must be positive");
        ChunkStore { store, chunk_size }
    }

    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    pub fn store(&self) -> &Arc<dyn Storage> {
        &self.store
    }

    /// Store one chunk under its content key; returns `(key, written)`
    /// where `written` is false when the chunk already existed (dedupe).
    pub fn put_chunk(&self, data: &[u8]) -> Result<(String, bool)> {
        let key = chunk_key(data);
        if self.store.exists(&key)? {
            return Ok((key, false));
        }
        self.store.put(&key, data)?;
        Ok((key, true))
    }

    /// Fetch one chunk by key and verify its content hash — a corrupted
    /// or substituted object fails loudly instead of decoding garbage.
    pub fn get_chunk(&self, key: &str) -> Result<Vec<u8>> {
        let data = self.store.get(key).with_context(|| format!("fetch chunk {key}"))?;
        let expect = chunk_key(&data);
        if expect != key {
            bail!("chunk {key}: content hash mismatch (got {expect})");
        }
        Ok(data)
    }

    /// Split `data` into chunks, upload only the missing ones, and write
    /// the reassembly manifest at `manifest_key`.
    pub fn put_blob(&self, manifest_key: &str, data: &[u8]) -> Result<PutStats> {
        let mut stats = PutStats { bytes_total: data.len() as u64, ..PutStats::default() };
        let mut entries = Vec::new();
        for chunk in data.chunks(self.chunk_size.max(1)) {
            let (key, written) = self.put_chunk(chunk)?;
            stats.chunks_total += 1;
            if written {
                stats.chunks_written += 1;
                stats.bytes_written += chunk.len() as u64;
            } else {
                stats.bytes_deduped += chunk.len() as u64;
            }
            entries.push(Json::obj(vec![
                ("key", Json::str(key)),
                ("len", Json::int(chunk.len() as i64)),
            ]));
        }
        let manifest = Json::obj(vec![
            ("blob_len", Json::int(data.len() as i64)),
            ("chunk_size", Json::int(self.chunk_size as i64)),
            ("chunks", Json::arr(entries)),
        ]);
        self.store
            .put(manifest_key, manifest.emit().as_bytes())
            .with_context(|| format!("write blob manifest {manifest_key}"))?;
        Ok(stats)
    }

    /// Reassemble the blob behind `manifest_key`, verifying every chunk's
    /// content hash and the declared lengths.
    pub fn get_blob(&self, manifest_key: &str) -> Result<Vec<u8>> {
        let manifest = self.read_manifest(manifest_key)?;
        let blob_len = manifest
            .get("blob_len")
            .as_usize()
            .with_context(|| format!("manifest {manifest_key}: missing blob_len"))?;
        let chunks = manifest
            .get("chunks")
            .as_arr()
            .with_context(|| format!("manifest {manifest_key}: missing chunks"))?;
        let mut out = Vec::with_capacity(blob_len);
        for (i, entry) in chunks.iter().enumerate() {
            let key = entry
                .get("key")
                .as_str()
                .with_context(|| format!("manifest {manifest_key}: chunk {i} missing key"))?;
            let len = entry
                .get("len")
                .as_usize()
                .with_context(|| format!("manifest {manifest_key}: chunk {i} missing len"))?;
            let data = self.get_chunk(key)?;
            if data.len() != len {
                bail!(
                    "manifest {manifest_key}: chunk {i} ({key}) is {} bytes, manifest says {len}",
                    data.len()
                );
            }
            out.extend_from_slice(&data);
        }
        if out.len() != blob_len {
            bail!(
                "manifest {manifest_key}: reassembled {} bytes, manifest says {blob_len}",
                out.len()
            );
        }
        Ok(out)
    }

    /// Parse the JSON manifest at `manifest_key`.
    pub fn read_manifest(&self, manifest_key: &str) -> Result<Json> {
        let bytes = self
            .store
            .get(manifest_key)
            .with_context(|| format!("read blob manifest {manifest_key}"))?;
        let text = std::str::from_utf8(&bytes)
            .with_context(|| format!("manifest {manifest_key}: not utf-8"))?;
        Json::parse(text).map_err(|e| anyhow::anyhow!("manifest {manifest_key}: {e}"))
    }
}

/// Content key of a chunk: `chunks/<32 hex digits of fnv1a-128>`.
pub fn chunk_key(data: &[u8]) -> String {
    format!("{CHUNK_PREFIX}{:032x}", fnv1a128(data))
}

/// FNV-1a, 128-bit variant (offset basis and prime per the FNV spec).
fn fnv1a128(data: &[u8]) -> u128 {
    const OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
    const PRIME: u128 = 0x0000000001000000000000000000013B;
    let mut h = OFFSET;
    for &b in data {
        h ^= b as u128;
        h = h.wrapping_mul(PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemObject;

    fn mem_chunks(chunk_size: usize) -> ChunkStore {
        ChunkStore::with_chunk_size(Arc::new(MemObject::new()), chunk_size)
    }

    #[test]
    fn fnv1a128_matches_known_vectors() {
        // Published FNV-1a 128-bit test vectors ("" and "a").
        assert_eq!(fnv1a128(b""), 0x6c62272e07bb014262b821756295c58d);
        assert_eq!(fnv1a128(b"a"), 0xd228cb696f1a8caf78912b704e4a8964);
    }

    #[test]
    fn blob_roundtrip_and_dedupe() {
        let cs = mem_chunks(8);
        let data: Vec<u8> = (0..50u8).collect();
        let first = cs.put_blob("blobs/a", &data).unwrap();
        assert_eq!(first.chunks_total, 7); // 6×8 + one 2-byte tail
        assert_eq!(first.chunks_written, 7);
        assert_eq!(first.bytes_written, 50);
        assert_eq!(cs.get_blob("blobs/a").unwrap(), data);
        // identical blob under another manifest: all chunks dedupe
        let second = cs.put_blob("blobs/b", &data).unwrap();
        assert_eq!(second.chunks_written, 0);
        assert_eq!(second.bytes_deduped, 50);
        assert_eq!(cs.get_blob("blobs/b").unwrap(), data);
    }

    #[test]
    fn shared_prefix_dedupes_partially() {
        let cs = mem_chunks(8);
        let a: Vec<u8> = (0..32u8).collect();
        let mut b = a.clone();
        b[31] = 99; // last chunk differs
        cs.put_blob("blobs/a", &a).unwrap();
        let stats = cs.put_blob("blobs/b", &b).unwrap();
        assert_eq!(stats.chunks_total, 4);
        assert_eq!(stats.chunks_written, 1);
        assert_eq!(stats.bytes_deduped, 24);
    }

    #[test]
    fn empty_blob_roundtrips() {
        let cs = mem_chunks(8);
        let stats = cs.put_blob("blobs/empty", &[]).unwrap();
        assert_eq!(stats.chunks_total, 0);
        assert_eq!(cs.get_blob("blobs/empty").unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn corrupted_chunk_is_detected() {
        let cs = mem_chunks(8);
        let data = vec![1u8; 16];
        cs.put_blob("blobs/x", &data).unwrap();
        let keys = cs.store().list(CHUNK_PREFIX).unwrap();
        cs.store().put(&keys[0], b"corrupt!").unwrap();
        let err = cs.get_blob("blobs/x").unwrap_err();
        assert!(format!("{err:#}").contains("hash mismatch"), "{err:#}");
    }
}
