//! Rank optimization — the paper's Algorithm 1.
//!
//! Sweep the decomposition rank from the Eq.-(5) nominal value `R` down to
//! the Eq.-(6) lower bound `R_min` (one full compression step), timing the
//! decomposed layer at each rank; pick the rank at the first/highest peak
//! of the step-time first derivative (the downhill edge of a hardware tile
//! band); fall back to the original dense layer if even the optimal rank
//! is no faster.
//!
//! Timing is abstracted behind [`LayerTimer`], with two backends:
//! - [`ModelTimer`]: the analytical device model (simulated V100 / Ascend /
//!   TPU — reproduces the paper's staircase deterministically),
//! - [`PjrtTimer`]: real measurements of builder-constructed computations
//!   on the PJRT client (the paper's platform-agnostic claim: the same
//!   sweep runs on any PJRT backend).
//!
//! Note on the paper's pseudo-code: Algorithm 1 writes `Δt(r) = t(r) −
//! t(r−1)` and `R_opt = argmax Δt`, which taken literally returns the rank
//! *above* the drop (e.g. 257, the slow side of the 256 boundary) — yet the
//! text says reducing 257 → 256 is the win. We define `Δt(r) = t(r+1) −
//! t(r)` (the gain obtained by stepping *down to* `r`) so `argmax` lands on
//! 256, matching the paper's intent.

use crate::devmodel::DeviceProfile;
use crate::lrd::{
    compression_ratio, svd_rank_for_compression, svd_rmin, tucker_rank_eq5, tucker_rmin_eq6,
    LayerShape,
};
use crate::runtime::builder::LayerBench;
use crate::runtime::Runtime;
use crate::util::stats;
use anyhow::Result;

/// Timing backend for Algorithm 1.
pub trait LayerTimer {
    fn backend(&self) -> String;
    /// Median time of the original dense layer.
    fn time_dense(&mut self, l: &LayerBench) -> Result<f64>;
    /// Median time of the decomposed layer at ranks (r1, r2).
    fn time_decomposed(&mut self, l: &LayerBench, r1: usize, r2: usize) -> Result<f64>;
}

/// Analytical backend over a [`DeviceProfile`].
pub struct ModelTimer(pub DeviceProfile);

impl LayerTimer for ModelTimer {
    fn backend(&self) -> String {
        self.0.name.to_string()
    }
    fn time_dense(&mut self, l: &LayerBench) -> Result<f64> {
        Ok(self.0.dense_fwd(l))
    }
    fn time_decomposed(&mut self, l: &LayerBench, r1: usize, r2: usize) -> Result<f64> {
        Ok(self.0.decomposed_fwd(l, r1, r2))
    }
}

/// Measured backend: compiles builder computations on the PJRT client and
/// times real executions (median of `reps`).
pub struct PjrtTimer<'a> {
    pub rt: &'a Runtime,
    pub warmup: usize,
    pub reps: usize,
}

impl<'a> PjrtTimer<'a> {
    pub fn new(rt: &'a Runtime) -> Self {
        PjrtTimer { rt, warmup: 2, reps: 7 }
    }

    fn time_exe(
        &self,
        comp: &xla::XlaComputation,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<f64> {
        let exe = self.rt.compile(comp, name)?;
        for _ in 0..self.warmup {
            exe.time_once(inputs)?;
        }
        let samples: Vec<f64> =
            (0..self.reps).map(|_| exe.time_once(inputs)).collect::<Result<_>>()?;
        Ok(stats::median(&samples))
    }
}

impl LayerTimer for PjrtTimer<'_> {
    fn backend(&self) -> String {
        format!("pjrt-{}", self.rt.platform())
    }
    fn time_dense(&mut self, l: &LayerBench) -> Result<f64> {
        let comp = l.dense_computation()?;
        self.time_exe(&comp, "dense", &l.make_inputs(None)?)
    }
    fn time_decomposed(&mut self, l: &LayerBench, r1: usize, r2: usize) -> Result<f64> {
        let comp = l.decomposed_computation(r1, r2)?;
        self.time_exe(&comp, "lrd", &l.make_inputs(Some((r1, r2)))?)
    }
}

/// One point of the rank sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub r: usize,
    pub t: f64,
    /// achieved compression ratio at this rank
    pub ratio: f64,
}

/// Result of Algorithm 1 on one layer.
#[derive(Clone, Debug)]
pub struct RankOptResult {
    pub shape: LayerShape,
    pub backend: String,
    /// Eq.-(5) nominal rank (sweep start).
    pub r_nominal: usize,
    /// Eq.-(6) lower bound (sweep end).
    pub r_min: usize,
    /// Chosen optimal rank.
    pub r_opt: usize,
    /// Sweep points ordered descending in `r` (R → R_min), stride 1.
    pub sweep: Vec<SweepPoint>,
    /// `Δt(r) = t(r+1) − t(r)`, aligned with `sweep[1..]`.
    pub delta: Vec<f64>,
    pub t_dense: f64,
    pub t_nominal: f64,
    pub t_opt: f64,
    /// True when even the optimal decomposition is no faster than dense —
    /// Algorithm 1 then keeps the original layer.
    pub use_original: bool,
}

impl RankOptResult {
    /// Throughput improvement of the chosen configuration vs vanilla LRD.
    pub fn speedup_vs_nominal(&self) -> f64 {
        self.t_nominal / self.effective_time()
    }
    /// Throughput improvement vs the dense layer.
    pub fn speedup_vs_dense(&self) -> f64 {
        self.t_dense / self.effective_time()
    }
    /// Time of what will actually run (dense if `use_original`).
    pub fn effective_time(&self) -> f64 {
        if self.use_original {
            self.t_dense
        } else {
            self.t_opt
        }
    }
}

/// Algorithm 1 configuration.
#[derive(Clone, Debug)]
pub struct RankOptConfig {
    pub alpha: f64,
    pub beta: f64,
    /// Sweep stride (1 = the paper's exhaustive sweep).
    pub stride: usize,
    /// Spatial positions (batch·H·W) used for the layer micro-benchmark.
    pub m: usize,
}

impl Default for RankOptConfig {
    fn default() -> Self {
        RankOptConfig { alpha: 2.0, beta: 1.0, stride: 1, m: 4096 }
    }
}

/// Run Algorithm 1 for one layer.
pub fn optimize_rank(
    timer: &mut dyn LayerTimer,
    shape: LayerShape,
    cfg: &RankOptConfig,
) -> Result<RankOptResult> {
    let (r_nominal, r_min) = if shape.is_linear() {
        (
            svd_rank_for_compression(shape.c, shape.s, cfg.alpha),
            svd_rmin(shape.c, shape.s, cfg.alpha),
        )
    } else {
        (
            tucker_rank_eq5(shape.c, shape.s, shape.k, cfg.alpha, cfg.beta),
            tucker_rmin_eq6(shape.c, shape.s, shape.k, cfg.alpha, cfg.beta),
        )
    };
    let r_min = r_min.max(1).min(r_nominal);
    let bench = LayerBench { m: cfg.m, c: shape.c, s: shape.s, k: shape.k };

    let t_dense = timer.time_dense(&bench)?;

    // Sweep r from R down to R_min (descending, stride cfg.stride).
    let mut sweep = Vec::new();
    let mut r = r_nominal;
    loop {
        let r2 = r2_of(r, cfg.beta, shape.s);
        let t = timer.time_decomposed(&bench, r, r2)?;
        sweep.push(SweepPoint { r, t, ratio: compression_ratio(&shape, r, r2) });
        if r <= r_min {
            break;
        }
        r = r.saturating_sub(cfg.stride).max(r_min);
    }

    // Δt(r) = t(r+stride) − t(r): the gain from stepping down *to* r.
    let delta: Vec<f64> = sweep.windows(2).map(|w| w[0].t - w[1].t).collect();

    // First (largest-r) peak of the derivative. stats::argmax returns the
    // first index on ties, and sweep is ordered descending in r, so this is
    // the paper's "first peak".
    let (r_opt, t_opt) = if delta.is_empty() {
        (sweep[0].r, sweep[0].t)
    } else {
        let i = stats::argmax(&delta).unwrap();
        (sweep[i + 1].r, sweep[i + 1].t)
    };

    let t_nominal = sweep[0].t;
    Ok(RankOptResult {
        shape,
        backend: timer.backend(),
        r_nominal,
        r_min,
        r_opt,
        sweep,
        delta,
        t_dense,
        t_nominal,
        t_opt,
        use_original: t_opt >= t_dense,
    })
}

/// r2 = round(β · r1), clamped to the output channels.
pub fn r2_of(r1: usize, beta: f64, s: usize) -> usize {
    (((r1 as f64) * beta).round() as usize).clamp(1, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v100() -> ModelTimer {
        ModelTimer(DeviceProfile::v100())
    }

    #[test]
    fn paper_layer_sweeps_to_tile_multiple() {
        // [512,512,3,3] @ 2x: nominal 309, Rmin ~242; on a tiled device the
        // optimum should land on a tile multiple (Fig. 2: 256 region).
        let mut t = ModelTimer(DeviceProfile::ascend910());
        let r = optimize_rank(
            &mut t,
            LayerShape::conv(512, 512, 3),
            &RankOptConfig { m: 14 * 14 * 32, ..Default::default() },
        )
        .unwrap();
        assert_eq!(r.r_nominal, 309);
        assert!((240..=254).contains(&r.r_min), "rmin {}", r.r_min);
        assert_eq!(r.r_opt % 16, 0, "r_opt {} not a cube multiple", r.r_opt);
        assert!(r.t_opt <= r.t_nominal);
        assert!(!r.use_original);
    }

    #[test]
    fn sweep_is_descending_and_complete() {
        let mut t = v100();
        let r = optimize_rank(&mut t, LayerShape::conv(128, 128, 3), &Default::default())
            .unwrap();
        for w in r.sweep.windows(2) {
            assert_eq!(w[0].r, w[1].r + 1);
        }
        assert_eq!(r.sweep.first().unwrap().r, r.r_nominal);
        assert_eq!(r.sweep.last().unwrap().r, r.r_min);
        assert_eq!(r.delta.len(), r.sweep.len() - 1);
    }

    #[test]
    fn ratio_monotone_in_sweep() {
        let mut t = v100();
        let r = optimize_rank(&mut t, LayerShape::conv(256, 256, 3), &Default::default())
            .unwrap();
        for w in r.sweep.windows(2) {
            assert!(w[1].ratio >= w[0].ratio, "compression grows as rank shrinks");
        }
        // band spans roughly [α, α+1]
        assert!(r.sweep[0].ratio >= 1.9);
        assert!(r.sweep.last().unwrap().ratio <= 3.3);
    }

    #[test]
    fn small_layer_keeps_original() {
        // A tiny layer where decomposition can't win (3 launches vs 1, all
        // overhead-bound) must fall back to the dense layer.
        let mut t = v100();
        let r = optimize_rank(
            &mut t,
            LayerShape::conv(64, 64, 3),
            &RankOptConfig { m: 64, ..Default::default() },
        )
        .unwrap();
        assert!(r.use_original);
        assert_eq!(r.effective_time(), r.t_dense);
    }

    #[test]
    fn linear_layer_svd_path() {
        let mut t = v100();
        let r = optimize_rank(
            &mut t,
            LayerShape::linear(512, 512),
            &RankOptConfig { m: 8192, ..Default::default() },
        )
        .unwrap();
        assert_eq!(r.r_nominal, 128);
        assert!(r.r_opt <= r.r_nominal && r.r_opt >= r.r_min);
        // the chosen rank sits on a tile boundary (v100 tile_n = 8)
        assert_eq!(r.r_opt % 8, 0, "r_opt {}", r.r_opt);
    }

    #[test]
    fn speedups_are_consistent() {
        let mut t = ModelTimer(DeviceProfile::ascend910());
        let r = optimize_rank(
            &mut t,
            LayerShape::conv(512, 512, 3),
            &RankOptConfig { m: 6272, ..Default::default() },
        )
        .unwrap();
        assert!(r.speedup_vs_nominal() >= 1.0);
        let eff = r.effective_time();
        assert!(eff <= r.t_dense || !r.use_original);
    }

    #[test]
    fn stride_reduces_sweep_cost() {
        let mut t = v100();
        let cfg = RankOptConfig { stride: 4, ..Default::default() };
        let r = optimize_rank(&mut t, LayerShape::conv(256, 256, 3), &cfg).unwrap();
        for w in r.sweep.windows(2) {
            let step = w[0].r - w[1].r;
            assert!(step == 4 || w[1].r == r.r_min);
        }
    }

    #[test]
    fn r2_of_beta() {
        assert_eq!(r2_of(100, 1.0, 512), 100);
        assert_eq!(r2_of(100, 2.0, 512), 200);
        assert_eq!(r2_of(100, 2.0, 150), 150); // clamped
        assert_eq!(r2_of(1, 0.25, 512), 1); // floor at 1
    }
}
