"""L2 building blocks — functional layers over explicit parameter dicts.

Params are flat ``{name: jnp.ndarray}`` dicts with dotted names
(``stage1.block0.conv1.core``). Every decomposable layer exists in a dense
and a decomposed form; the decomposed forms route their 1x1 / FC products
through the L1 Pallas kernel (``kernels.lowrank``).

Weight layouts (match the AOT manifest consumed by the rust runtime):
  - linear:        ``w [C, S]``, ``bias [S]``
  - conv (dense):  ``w [k, k, C, S]`` (HWIO), ``bias [S]``
  - linear/1x1 SVD factors:   ``a [C, r]``, ``b [r, S]``
  - conv Tucker2 factors:     ``first [C, r1]``, ``core [k, k, r1, r2]``,
                              ``last [r2, S]``
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.lowrank import lowrank_matmul

# Pallas must run interpret=True on the CPU PJRT plugin (see kernels doc).
INTERPRET = True

# Block size for the low-rank kernel's M dimension. On TPU this would be
# 128 (MXU tile, see kernels/lowrank.py); on the CPU PJRT target a grid of
# blocks lowers to an HLO while-loop with dynamic-update-slices, which the
# 2023-vintage XLA CPU backend executes far slower than one fused matmul
# chain — so CPU artifacts use a single whole-M block.
BLOCK_M = 1 << 30


# ---------------------------------------------------------------------------
# primitive layers
# ---------------------------------------------------------------------------

def dense_linear(p, name, x):
    """x [M, C] @ w [C, S] + bias."""
    return x @ p[f"{name}.w"] + p[f"{name}.bias"]


def svd_linear(p, name, x):
    """Decomposed FC: fused low-rank product through the Pallas kernel."""
    y = lowrank_matmul(x, p[f"{name}.a"], p[f"{name}.b"], block_m=BLOCK_M, interpret=INTERPRET)
    return y + p[f"{name}.bias"]


def conv2d(p, name, x, stride=1):
    """Dense kxk conv, NHWC/HWIO."""
    y = lax.conv_general_dilated(
        x,
        p[f"{name}.w"],
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p[f"{name}.bias"]


def pointwise(p_first, p_last, x):
    """Fused pair of 1x1 convs (C->r1->S) via the low-rank kernel over
    flattened pixels. Used when the Tucker core is the identity-free path."""
    n, h, w, c = x.shape
    y = lowrank_matmul(
        x.reshape(n * h * w, c), p_first, p_last, block_m=BLOCK_M, interpret=INTERPRET
    )
    return y.reshape(n, h, w, -1)


def pointwise_single(x, w):
    """Single 1x1 conv as a flat matmul. x NHWC, w [C, S]."""
    n, h, wd, c = x.shape
    return (x.reshape(n * h * wd, c) @ w).reshape(n, h, wd, -1)


def tucker_conv(p, name, x, stride=1):
    """Tucker2-decomposed conv: 1x1 -> kxk core (carries the stride) -> 1x1.

    The two 1x1 stages are rank-r matmuls; the input-side one feeds the
    core conv so it cannot be fused with the output-side one when k > 1 —
    but each is still a Pallas-friendly flat matmul.
    """
    first = p[f"{name}.first"]  # [C, r1]
    core = p[f"{name}.core"]  # [k, k, r1, r2]
    last = p[f"{name}.last"]  # [r2, S]
    t = pointwise_single(x, first)
    t = lax.conv_general_dilated(
        t,
        core,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = pointwise_single(t, last)
    return y + p[f"{name}.bias"]


def svd_conv1x1(p, name, x, stride=1):
    """SVD-decomposed 1x1 conv (used for shortcut projections)."""
    if stride > 1:
        x = x[:, ::stride, ::stride, :]
    n, h, w, c = x.shape
    y = lowrank_matmul(
        x.reshape(n * h * w, c), p[f"{name}.a"], p[f"{name}.b"],
        block_m=BLOCK_M, interpret=INTERPRET,
    )
    return y.reshape(n, h, w, -1) + p[f"{name}.bias"]


def dense_conv1x1(p, name, x, stride=1):
    if stride > 1:
        x = x[:, ::stride, ::stride, :]
    return pointwise_single(x, p[f"{name}.w"]) + p[f"{name}.bias"]


def group_norm(p, name, x, groups=8, eps=1e-5):
    """Stateless GroupNorm (no running stats -> clean AOT train steps)."""
    shape = x.shape
    c = shape[-1]
    g = min(groups, c)
    while c % g != 0:
        g -= 1
    xg = x.reshape(*shape[:-1], g, c // g)
    axes = tuple(range(1, xg.ndim - 2)) + (xg.ndim - 1,)
    mean = xg.mean(axis=axes, keepdims=True)
    var = xg.var(axis=axes, keepdims=True)
    xn = ((xg - mean) / jnp.sqrt(var + eps)).reshape(shape)
    return xn * p[f"{name}.gamma"] + p[f"{name}.beta"]


def layer_norm(p, name, x, eps=1e-5):
    mean = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * p[f"{name}.gamma"] + p[f"{name}.beta"]


def softmax_cross_entropy(logits, labels):
    shifted = logits - logits.max(-1, keepdims=True)
    logz = jnp.log(jnp.exp(shifted).sum(-1))
    logp = shifted - logz[..., None]
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return nll.mean()


def num_correct(logits, labels):
    return (logits.argmax(-1) == labels).sum().astype(jnp.float32)


# ---------------------------------------------------------------------------
# layer-spec driven dispatch
# ---------------------------------------------------------------------------
# A model config describes each decomposable layer as
#   {"kind": "dense"}                      keep original
#   {"kind": "svd", "rank": r}             FC / 1x1 SVD factors
#   {"kind": "tucker", "r1": r1, "r2": r2} kxk conv Tucker2
# The config is produced by configs.py (vanilla Eq.5 ranks or
# hardware-snapped "rankopt" ranks) and recorded in the AOT manifest.


def apply_conv(p, cfg, name, x, stride=1):
    kind = cfg[name]["kind"]
    if kind == "dense":
        return conv2d(p, name, x, stride=stride)
    if kind == "tucker":
        return tucker_conv(p, name, x, stride=stride)
    raise ValueError(f"bad conv kind {kind} for {name}")


def apply_conv1x1(p, cfg, name, x, stride=1):
    kind = cfg[name]["kind"]
    if kind == "dense":
        return dense_conv1x1(p, name, x, stride=stride)
    if kind == "svd":
        return svd_conv1x1(p, name, x, stride=stride)
    raise ValueError(f"bad 1x1 kind {kind} for {name}")


def apply_linear(p, cfg, name, x):
    kind = cfg[name]["kind"]
    if kind == "dense":
        return dense_linear(p, name, x)
    if kind == "svd":
        return svd_linear(p, name, x)
    raise ValueError(f"bad linear kind {kind} for {name}")
