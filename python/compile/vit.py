"""L2 — small Vision Transformer (pre-LN, mean-pool head).

Per the paper's ViT experiment, the decomposable layers are the two FCs in
each block's feed-forward module plus the patch-embedding FC; attention
projections stay dense. ``cfg`` decides dense vs SVD per layer.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import layers as L
from .configs import VIT_MINI


def _attention(p, pre, x, heads):
    """Standard multi-head self-attention (dense projections)."""
    n, t, d = x.shape
    hd = d // heads
    qkv = L.dense_linear(p, f"{pre}.qkv", x.reshape(n * t, d)).reshape(n, t, 3, heads, hd)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]  # [n, t, h, hd]
    q = q.transpose(0, 2, 1, 3)  # [n, h, t, hd]
    k = k.transpose(0, 2, 3, 1)  # [n, h, hd, t]
    v = v.transpose(0, 2, 1, 3)
    att = jnp.einsum("nhtd,nhds->nhts", q, k) / jnp.sqrt(jnp.float32(hd))
    att = jnp.exp(att - att.max(-1, keepdims=True))
    att = att / att.sum(-1, keepdims=True)
    y = jnp.einsum("nhts,nhsd->nhtd", att, v).transpose(0, 2, 1, 3).reshape(n * t, d)
    return L.dense_linear(p, f"{pre}.out", y).reshape(n, t, d)


def _mlp(p, cfg, pre, x):
    n, t, d = x.shape
    y = L.apply_linear(p, cfg, f"{pre}.fc1", x.reshape(n * t, d))
    y = jnp.maximum(y, 0.0)  # relu (gelu adds lowering noise for no gain here)
    y = L.apply_linear(p, cfg, f"{pre}.fc2", y)
    return y.reshape(n, t, d)


def vit_apply(p, cfg, x, spec=VIT_MINI):
    """x: [N, H, W, 3] -> logits [N, classes]."""
    n, h, w, c = x.shape
    ps = spec["patch"]
    d = spec["dim"]
    gh, gw = h // ps, w // ps
    # patchify: [N, gh, ps, gw, ps, C] -> [N, gh*gw, ps*ps*C]
    patches = (
        x.reshape(n, gh, ps, gw, ps, c)
        .transpose(0, 1, 3, 2, 4, 5)
        .reshape(n * gh * gw, ps * ps * c)
    )
    tok = L.apply_linear(p, cfg, "embed", patches).reshape(n, gh * gw, d)
    tok = tok + p["pos_embed"]
    for i in range(spec["depth"]):
        pre = f"block{i}"
        t = tok.reshape(n * gh * gw, d)
        a = L.layer_norm(p, f"{pre}.ln1", t).reshape(n, gh * gw, d)
        tok = tok + _attention(p, f"{pre}.attn", a, spec["heads"])
        t = tok.reshape(n * gh * gw, d)
        m = L.layer_norm(p, f"{pre}.ln2", t).reshape(n, gh * gw, d)
        tok = tok + _mlp(p, cfg, f"{pre}.mlp", m)
    t = L.layer_norm(p, "ln_f", tok.reshape(n * gh * gw, d)).reshape(n, gh * gw, d)
    pooled = t.mean(axis=1)
    return L.apply_linear(p, cfg, "head", pooled)
