"""L2 — SGD(momentum, weight-decay) train steps with structural freezing.

The paper's freezing (Algorithm 2) is implemented *structurally*: each
freeze pattern yields a separate train step in which the frozen factors are
plain (non-differentiated) inputs. `jax.grad` then never builds their
backward graph, so the lowered HLO genuinely contains less backprop work —
the same saving `requires_grad=False` gives PyTorch, but visible to the AOT
compiler.

Freeze patterns over a decomposition config:
  - "none": everything trainable (vanilla LRD / original model)
  - "a" (even epochs): SVD -> freeze factor `a` (L_r(0)), train `b`;
         Tucker -> freeze `first`+`last` (the 1x1s), train `core`
  - "b" (odd epochs): the complement.
Auxiliary params (biases, norms, pos-embed, dense layers) always train.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .configs import param_shapes

MOMENTUM = 0.9
WEIGHT_DECAY = 1e-4


def frozen_names_for_pattern(cfg, pattern: str):
    """Set of parameter names frozen under a pattern (paper Algorithm 2)."""
    assert pattern in ("none", "a", "b"), pattern
    frozen = set()
    if pattern == "none":
        return frozen
    for lname, lcfg in cfg.items():
        kind = lcfg["kind"]
        if kind == "svd":
            frozen.add(f"{lname}.a" if pattern == "a" else f"{lname}.b")
        elif kind == "tucker":
            if pattern == "a":
                frozen.update({f"{lname}.first", f"{lname}.last"})
            else:
                frozen.add(f"{lname}.core")
    return frozen


def split_params(model: str, cfg, pattern: str):
    """Ordered (trainable_names, frozen_names) for a freeze pattern."""
    shapes = param_shapes(model, cfg)
    frozen = frozen_names_for_pattern(cfg, pattern)
    trainable = [n for n in shapes if n not in frozen]
    frozen_list = [n for n in shapes if n in frozen]
    return trainable, frozen_list


def make_train_step(apply_fn, cfg, trainable_names, frozen_names,
                    momentum=MOMENTUM, wd=WEIGHT_DECAY):
    """Build `step(*trainable, *frozen, *mom, x, y, lr) -> (*new_trainable,
    *new_mom, loss, correct)` with flat positional arrays (AOT-friendly)."""
    n_tr = len(trainable_names)
    n_fz = len(frozen_names)

    def step(*args):
        tr_list = args[:n_tr]
        fz_list = args[n_tr:n_tr + n_fz]
        mom_list = args[n_tr + n_fz:n_tr + n_fz + n_tr]
        x, y, lr = args[n_tr + n_fz + n_tr:]
        fz = dict(zip(frozen_names, fz_list))

        def loss_fn(tr_tuple):
            p = dict(zip(trainable_names, tr_tuple))
            p.update(fz)
            logits = apply_fn(p, cfg, x)
            return L.softmax_cross_entropy(logits, y), logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            tuple(tr_list)
        )
        new_tr, new_mom = [], []
        for w, g, m in zip(tr_list, grads, mom_list):
            g = g + wd * w
            nm = momentum * m + g
            new_tr.append(w - lr * nm)
            new_mom.append(nm)
        correct = L.num_correct(logits, y)
        return tuple(new_tr) + tuple(new_mom) + (loss, correct)

    return step


def make_infer(apply_fn, cfg, param_names):
    """Build `infer(*params, x) -> logits` with flat positional arrays."""
    def infer(*args):
        p = dict(zip(param_names, args[:-1]))
        return apply_fn(p, cfg, args[-1])

    return infer


# ---------------------------------------------------------------------------
# initialization (dense models only — decomposed weights come from the rust
# LRD engine operating on the trained dense checkpoint)
# ---------------------------------------------------------------------------

def init_params(model: str, cfg, seed: int = 0):
    """He-normal init for weights, zeros for biases, ones/zeros for norms."""
    shapes = param_shapes(model, cfg)
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in shapes.items():
        key, sub = jax.random.split(key)
        if name.endswith(".bias") or name.endswith(".beta"):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name.endswith(".gamma"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "pos_embed":
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in = int(jnp.prod(jnp.array(shape[:-1]))) if len(shape) > 1 else shape[0]
            std = (2.0 / max(1, fan_in)) ** 0.5
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def lr_cosine(base_lr: float, step: int, total_steps: int) -> float:
    """Cosine schedule (paper: ImageNet fine-tunes use cosine LR)."""
    import math

    t = min(step, total_steps) / max(1, total_steps)
    return 0.5 * base_lr * (1.0 + math.cos(math.pi * t))
