"""L1 — fused low-rank product Pallas kernel.

The hot spot of every LRD-decomposed layer is the two-matmul chain

    y = (x @ a) @ b        x: [M, C], a: [C, r], b: [r, S]

where ``a = U'.sqrt(S')`` and ``b = sqrt(S').V'^T`` are the SVD factors
(paper Eq. 2). Executed as two separate layers (what the paper's PyTorch
implementation does) the rank-r intermediate ``t = x @ a`` round-trips
through HBM; this kernel keeps it in VMEM scratch and feeds both products
to the MXU back-to-back.

TPU mapping (DESIGN.md §Hardware-Adaptation):
  - grid over M in blocks of ``bm`` (default 128 = MXU tile height),
  - ``a`` and ``b`` are small (rank-r factors) and live fully in VMEM,
  - the intermediate ``t[bm, r]`` is a VMEM scratch buffer, never spilled,
  - both matmuls run at f32 on the MXU with
    ``preferred_element_type=float32``.

On this image Pallas must run ``interpret=True`` (the CPU PJRT plugin
cannot execute Mosaic custom-calls), which lowers the kernel to plain HLO
ops — numerically identical, so correctness transfers; TPU performance is
estimated analytically in ``rust/src/devmodel``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU systolic array is 128x128; (8, 128) is the f32 VREG tile.
MXU_TILE = 128
SUBLANE = 8


def _pick_block_m(m: int, bm: int) -> int:
    """Largest block <= bm that divides m, preferring MXU-aligned sizes."""
    if m <= bm:
        return m
    for cand in (bm, MXU_TILE, 64, 32, 16, SUBLANE):
        if cand <= bm and m % cand == 0:
            return cand
    # fall back to the largest divisor of m not exceeding bm
    for cand in range(min(bm, m), 0, -1):
        if m % cand == 0:
            return cand
    return m


def _lowrank_kernel(x_ref, a_ref, b_ref, o_ref, acc_ref):
    """One grid step: o[bm, S] = (x[bm, C] @ a[C, r]) @ b[r, S]."""
    # First product -> VMEM scratch (never leaves the core's memory).
    acc_ref[...] = jnp.dot(
        x_ref[...], a_ref[...], preferred_element_type=jnp.float32
    )
    # Second product straight from scratch.
    o_ref[...] = jnp.dot(
        acc_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _lowrank_pallas(x, a, b, block_m: int, interpret: bool):
    """Raw fused kernel invocation (no AD)."""
    m, c = x.shape
    c2, r = a.shape
    r2, s = b.shape
    assert c == c2 and r == r2, f"shape mismatch {x.shape} {a.shape} {b.shape}"
    bm = _pick_block_m(m, block_m)
    grid = (m // bm,)

    return pl.pallas_call(
        _lowrank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
            pl.BlockSpec((c, r), lambda i: (0, 0)),
            pl.BlockSpec((r, s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, s), jnp.float32),
        scratch_shapes=[pltpu_scratch((bm, r))],
        interpret=interpret,
    )(x, a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _lowrank_core(x, a, b, block_m, interpret):
    return _lowrank_pallas(x, a, b, block_m, interpret)


def _lowrank_fwd(x, a, b, block_m, interpret):
    return _lowrank_pallas(x, a, b, block_m, interpret), (x, a, b)


def _lowrank_bwd(block_m, interpret, res, g):
    """Backward pass, itself built on the fused kernel where it applies.

    y = x a b  =>  dx = g bT aT   (another low-rank product -> same kernel)
                   da = xT (g bT)
                   db = (x a)T g
    The rank-r intermediates (g bT and x a) are shared between the factor
    grads and recomputed once each — no O(M*C*S) buffer is ever formed.
    """
    x, a, b = res
    # dx via the fused kernel: (g @ bT) @ aT
    dx = _lowrank_pallas(g, b.T, a.T, block_m, interpret)
    g_bt = g @ b.T          # [M, r]
    x_a = x @ a             # [M, r]
    da = x.T @ g_bt         # [C, r]
    db = x_a.T @ g          # [r, S]
    return dx, da, db


_lowrank_core.defvjp(_lowrank_fwd, _lowrank_bwd)


def lowrank_matmul(x, a, b, *, block_m: int = MXU_TILE, interpret: bool = True):
    """Fused ``(x @ a) @ b`` via Pallas, differentiable.

    Args:
      x: ``[M, C]`` activations (M = batch*tokens or batch*H*W).
      a: ``[C, r]`` input-side factor.
      b: ``[r, S]`` output-side factor.
      block_m: target M-block (rounded down to a divisor of M).
      interpret: run the kernel in interpret mode (required on CPU).

    Returns:
      ``[M, S]`` float32.
    """
    return _lowrank_core(x, a, b, block_m, interpret)


def pltpu_scratch(shape):
    """VMEM scratch spec; uses the TPU memory space when available and a
    generic pallas scratch in interpret mode."""
    try:  # pragma: no cover - environment dependent
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, jnp.float32)
    except Exception:  # pragma: no cover
        return pl.Scratch(shape, jnp.float32)


def lowrank_vmem_bytes(m_block: int, c: int, r: int, s: int) -> int:
    """VMEM footprint (bytes, f32) of one grid step — used by the TPU
    performance estimate in rust's devmodel and reported in EXPERIMENTS.md."""
    floats = m_block * c + c * r + r * s + m_block * r + m_block * s
    return 4 * floats


def lowrank_mxu_flops(m: int, c: int, r: int, s: int) -> int:
    """MXU FLOPs of the fused product (2mnk per matmul)."""
    return 2 * m * c * r + 2 * m * r * s
