"""Pure-jnp correctness oracles for the Pallas kernels and model layers.

Everything here is deliberately naive jnp — the reference semantics that
pytest/hypothesis compare the kernels and the AOT-lowered graphs against.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def lowrank_matmul_ref(x, a, b):
    """y = (x @ a) @ b, the unfused two-matmul chain."""
    return (x @ a) @ b


def dense_linear_ref(x, w, bias=None):
    """y = x @ w (+ bias)."""
    y = x @ w
    if bias is not None:
        y = y + bias
    return y


def conv2d_ref(x, w, stride=1, padding="SAME"):
    """NHWC x HWIO convolution."""
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def pointwise_conv_ref(x, w):
    """1x1 conv as a matmul over flattened pixels: x NHWC, w [C, S]."""
    n, h, wd, c = x.shape
    y = x.reshape(n * h * wd, c) @ w
    return y.reshape(n, h, wd, -1)


def tucker_conv_ref(x, first, core, last, stride=1, padding="SAME"):
    """Tucker2-decomposed conv: 1x1 (C->r1), kxk core (r1->r2), 1x1 (r2->S).

    first: [C, r1], core: [k, k, r1, r2] (HWIO), last: [r2, S].
    The spatial stride lives on the core conv, matching the paper's Fig. 1.
    """
    t = pointwise_conv_ref(x, first)
    t = conv2d_ref(t, core, stride=stride, padding=padding)
    return pointwise_conv_ref(t, last)


def group_norm_ref(x, gamma, beta, groups=8, eps=1e-5):
    """GroupNorm over NHWC (or N,T,C with trailing channel dim)."""
    orig_shape = x.shape
    c = orig_shape[-1]
    g = min(groups, c)
    while c % g != 0:
        g -= 1
    xg = x.reshape(*orig_shape[:-1], g, c // g)
    axes = tuple(range(1, xg.ndim - 2)) + (xg.ndim - 1,)
    mean = xg.mean(axis=axes, keepdims=True)
    var = xg.var(axis=axes, keepdims=True)
    xn = (xg - mean) / jnp.sqrt(var + eps)
    return xn.reshape(orig_shape) * gamma + beta


def layer_norm_ref(x, gamma, beta, eps=1e-5):
    mean = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta


def softmax_cross_entropy_ref(logits, labels):
    """Mean cross-entropy; labels are int class ids."""
    logz = jnp.log(jnp.sum(jnp.exp(logits - logits.max(-1, keepdims=True)), -1))
    logp = logits - logits.max(-1, keepdims=True) - logz[..., None]
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return nll.mean()
