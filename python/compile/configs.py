"""Model zoo specs + decomposition configs (mirrors rust `lrd::plan`).

The rank formulas here are the paper's Eq. (5)/(6) and the SVD closed form;
rust re-implements them in `rust/src/lrd` and the two are pinned against
each other by tests (e.g. [512,512,3,3] @ 2x -> rank 309).

A "model config" maps every decomposable layer to
    {"kind": "dense"} | {"kind": "svd", "rank": r}
  | {"kind": "tucker", "r1": r1, "r2": r2}
plus bookkeeping (r_min for the rank-opt sweep band). Variants:
  - orig:    everything dense
  - lrd:     vanilla Eq.-(5) ranks
  - rankopt: Eq.-(5) ranks snapped to the device tile (rank quantization)
"""

from __future__ import annotations

import math

# ---------------------------------------------------------------------------
# rank formulas (paper Eq. 5/6 + SVD closed form)
# ---------------------------------------------------------------------------

def svd_rank(c: int, s: int, alpha: float) -> int:
    """Rank giving compression alpha on an FC/1x1 layer: r = CS/(a(C+S))."""
    return max(1, math.floor(c * s / (alpha * (c + s))))


def tucker_rank_eq5(c: int, s: int, k: int, alpha: float, beta: float = 1.0) -> int:
    b_term = (c + beta * s) / (beta * k * k)
    disc = b_term * b_term + 4.0 * c * s / (beta * alpha)
    return max(1, math.floor((-b_term + math.sqrt(disc)) / 2.0))


def tucker_rmin_eq6(c: int, s: int, k: int, alpha: float, beta: float = 1.0) -> int:
    return tucker_rank_eq5(c, s, k, alpha + 1.0, beta)


def svd_rmin(c: int, s: int, alpha: float) -> int:
    return svd_rank(c, s, alpha + 1.0)


def snap_rank(r: int, r_min: int, tile: int) -> int:
    """Rank quantization: snap down to a tile multiple, never below r_min;
    round up instead when that's closer and still near the nominal rank."""
    down = (r // tile) * tile
    if down >= max(r_min, 1):
        return down
    up = ((r + tile - 1) // tile) * tile
    if up <= r + tile // 2:
        return up
    return r


def decomposed_params(c, s, k, r1, r2):
    if k == 1:
        return c * r1 + r1 * s
    return c * r1 + r1 * r2 * k * k + r2 * s


# ---------------------------------------------------------------------------
# model zoo
# ---------------------------------------------------------------------------
# Layer inventory entries: (name, type, meta)
#   type "conv":   meta = dict(c, s, k, stride)
#   type "conv1x1":meta = dict(c, s, stride)      (shortcut projections)
#   type "linear": meta = dict(c, s)
# Non-decomposable params (norms, biases) are implied by the model builders.

RESNET_MINI = {
    "name": "resnet_mini",
    "image": (32, 32, 3),
    "classes": 10,
    "stem_channels": 32,
    "stages": [  # (channels, blocks, stride of first block)
        (32, 2, 1),
        (64, 2, 2),
        (128, 2, 2),
    ],
    "train_batch": 64,
    "infer_batch": 128,
}

VIT_MINI = {
    "name": "vit_mini",
    "image": (32, 32, 3),
    "classes": 10,
    "patch": 4,
    "dim": 128,
    "depth": 4,
    "heads": 4,
    "mlp_dim": 512,
    "train_batch": 64,
    "infer_batch": 128,
}

MODELS = {"resnet_mini": RESNET_MINI, "vit_mini": VIT_MINI}


def resnet_layers(spec):
    """Decomposable layer inventory for the ResNet spec."""
    layers = [("stem", "conv", dict(c=spec["image"][2], s=spec["stem_channels"], k=3, stride=1))]
    c_in = spec["stem_channels"]
    for si, (ch, blocks, stride) in enumerate(spec["stages"]):
        for bi in range(blocks):
            st = stride if bi == 0 else 1
            pre = f"stage{si}.block{bi}"
            layers.append((f"{pre}.conv1", "conv", dict(c=c_in, s=ch, k=3, stride=st)))
            layers.append((f"{pre}.conv2", "conv", dict(c=ch, s=ch, k=3, stride=1)))
            if st != 1 or c_in != ch:
                layers.append((f"{pre}.down", "conv1x1", dict(c=c_in, s=ch, stride=st)))
            c_in = ch
    layers.append(("head", "linear", dict(c=c_in, s=spec["classes"])))
    return layers


def vit_layers(spec):
    """Decomposable layer inventory for the ViT spec (paper: the two MLP
    FCs per block + the patch-embedding FC are decomposed)."""
    d, mlp = spec["dim"], spec["mlp_dim"]
    patch_in = spec["patch"] * spec["patch"] * spec["image"][2]
    layers = [("embed", "linear", dict(c=patch_in, s=d))]
    for i in range(spec["depth"]):
        pre = f"block{i}"
        layers.append((f"{pre}.attn.qkv", "linear", dict(c=d, s=3 * d)))
        layers.append((f"{pre}.attn.out", "linear", dict(c=d, s=d)))
        layers.append((f"{pre}.mlp.fc1", "linear", dict(c=d, s=mlp)))
        layers.append((f"{pre}.mlp.fc2", "linear", dict(c=mlp, s=d)))
    layers.append(("head", "linear", dict(c=d, s=spec["classes"])))
    return layers


def model_layers(model: str):
    if model == "resnet_mini":
        return resnet_layers(RESNET_MINI)
    if model == "vit_mini":
        return vit_layers(VIT_MINI)
    raise KeyError(model)


# Layers the paper does NOT decompose for ViT (attention projections stay
# dense; only FFN FCs + embedding are decomposed).
VIT_DENSE_ALWAYS = ("attn.qkv", "attn.out")


def build_config(model: str, variant: str, alpha: float = 2.0, beta: float = 1.0,
                 tile: int = 16):
    """Build the per-layer decomposition config for a model variant."""
    assert variant in ("orig", "lrd", "rankopt"), variant
    cfg = {}
    for name, ltype, meta in model_layers(model):
        if variant == "orig":
            cfg[name] = {"kind": "dense"}
            continue
        c, s = meta["c"], meta["s"]
        if model == "vit_mini" and any(name.endswith(d) for d in VIT_DENSE_ALWAYS):
            cfg[name] = {"kind": "dense"}
            continue
        if ltype == "conv" and meta["k"] > 1:
            k = meta["k"]
            # Eq. 5 can exceed the mode rank for skewed layers (e.g. a
            # 3-channel stem): clamp to the multilinear rank bound.
            r = min(tucker_rank_eq5(c, s, k, alpha, beta), c)
            rmin = min(tucker_rmin_eq6(c, s, k, alpha, beta), r)
            if variant == "rankopt":
                r = snap_rank(r, rmin, tile)
            r = min(r, c)
            r2 = max(1, min(s, round(beta * r)))
            if decomposed_params(c, s, k, r, r2) >= c * s * k * k:
                cfg[name] = {"kind": "dense"}  # decomposition doesn't pay
            else:
                cfg[name] = {"kind": "tucker", "r1": r, "r2": r2, "r_min": rmin}
        else:  # linear or conv1x1 -> SVD
            full = min(c, s)
            r = min(svd_rank(c, s, alpha), full)
            rmin = min(svd_rmin(c, s, alpha), r)
            if variant == "rankopt":
                r = snap_rank(r, rmin, tile)
            r = min(r, full)
            if decomposed_params(c, s, 1, r, r) >= c * s:
                cfg[name] = {"kind": "dense"}
            else:
                cfg[name] = {"kind": "svd", "rank": r, "r_min": rmin}
    return cfg


# ---------------------------------------------------------------------------
# parameter shape inventories
# ---------------------------------------------------------------------------

def param_shapes(model: str, cfg):
    """Ordered {name: shape} for all trainable params of a model variant.

    Order is deterministic (layer inventory order, then auxiliary params) —
    the AOT manifest and the rust runtime both rely on it.
    """
    shapes = {}

    def add_decomposable(name, ltype, meta):
        kind = cfg[name]["kind"]
        c, s = meta["c"], meta["s"]
        if ltype == "conv" and meta["k"] > 1:
            k = meta["k"]
            if kind == "dense":
                shapes[f"{name}.w"] = (k, k, c, s)
            else:
                r1, r2 = cfg[name]["r1"], cfg[name]["r2"]
                shapes[f"{name}.first"] = (c, r1)
                shapes[f"{name}.core"] = (k, k, r1, r2)
                shapes[f"{name}.last"] = (r2, s)
            shapes[f"{name}.bias"] = (s,)
        elif ltype == "conv1x1":
            if kind == "dense":
                shapes[f"{name}.w"] = (c, s)
            else:
                r = cfg[name]["rank"]
                shapes[f"{name}.a"] = (c, r)
                shapes[f"{name}.b"] = (r, s)
            shapes[f"{name}.bias"] = (s,)
        else:  # linear
            if kind == "dense":
                shapes[f"{name}.w"] = (c, s)
            else:
                r = cfg[name]["rank"]
                shapes[f"{name}.a"] = (c, r)
                shapes[f"{name}.b"] = (r, s)
            shapes[f"{name}.bias"] = (s,)

    if model == "resnet_mini":
        spec = RESNET_MINI
        for name, ltype, meta in resnet_layers(spec):
            add_decomposable(name, ltype, meta)
            # norms: one GroupNorm after each conv (not after head/down)
            if ltype == "conv":
                shapes[f"{name}.gn.gamma"] = (meta["s"],)
                shapes[f"{name}.gn.beta"] = (meta["s"],)
    elif model == "vit_mini":
        spec = VIT_MINI
        d = spec["dim"]
        for name, ltype, meta in vit_layers(spec):
            add_decomposable(name, ltype, meta)
        for i in range(spec["depth"]):
            shapes[f"block{i}.ln1.gamma"] = (d,)
            shapes[f"block{i}.ln1.beta"] = (d,)
            shapes[f"block{i}.ln2.gamma"] = (d,)
            shapes[f"block{i}.ln2.beta"] = (d,)
        shapes["pos_embed"] = ((spec["image"][0] // spec["patch"]) ** 2, d)
        shapes["ln_f.gamma"] = (d,)
        shapes["ln_f.beta"] = (d,)
    else:
        raise KeyError(model)
    return shapes


def total_params(shapes) -> int:
    return sum(int(math.prod(s)) for s in shapes.values())
