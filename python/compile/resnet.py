"""L2 — CIFAR-scale ResNet (pre-activation basic blocks, GroupNorm).

Functional: ``resnet_apply(params, cfg, x) -> logits`` where ``cfg`` is a
decomposition config from ``configs.build_config``. The same function
serves the original and every decomposed variant — the config decides which
layers route through the Pallas low-rank kernels.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import layers as L
from .configs import RESNET_MINI


def _block(p, cfg, pre, x, c_in, ch, stride):
    """Basic residual block: conv-gn-relu, conv-gn, (+shortcut), relu."""
    y = L.apply_conv(p, cfg, f"{pre}.conv1", x, stride=stride)
    y = L.group_norm(p, f"{pre}.conv1.gn", y)
    y = jnp.maximum(y, 0.0)
    y = L.apply_conv(p, cfg, f"{pre}.conv2", y, stride=1)
    y = L.group_norm(p, f"{pre}.conv2.gn", y)
    if stride != 1 or c_in != ch:
        sc = L.apply_conv1x1(p, cfg, f"{pre}.down", x, stride=stride)
    else:
        sc = x
    return jnp.maximum(y + sc, 0.0)


def resnet_apply(p, cfg, x, spec=RESNET_MINI):
    """x: [N, H, W, 3] float32 -> logits [N, classes]."""
    y = L.apply_conv(p, cfg, "stem", x, stride=1)
    y = L.group_norm(p, "stem.gn", y)
    y = jnp.maximum(y, 0.0)
    c_in = spec["stem_channels"]
    for si, (ch, blocks, stride) in enumerate(spec["stages"]):
        for bi in range(blocks):
            st = stride if bi == 0 else 1
            y = _block(p, cfg, f"stage{si}.block{bi}", y, c_in, ch, st)
            c_in = ch
    y = y.mean(axis=(1, 2))  # global average pool -> [N, C]
    return L.apply_linear(p, cfg, "head", y)
