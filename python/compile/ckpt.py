"""Binary checkpoint format shared between python (writer at build time)
and rust (`rust/src/checkpoint`, reader/writer on the training path).

Layout (little-endian):
    magic   b"LRTA"  | version u32 (=1) | count u32
    per tensor:
        name_len u32 | name utf-8 | ndim u32 | dims u32[ndim] | f32 data
Tensors are written in sorted-name order for determinism.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"LRTA"
VERSION = 1


def save(path: str, params: dict) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(params)))
        for name in sorted(params):
            arr = np.asarray(params[name], dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes(order="C"))


def load(path: str) -> dict:
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        version, count = struct.unpack("<II", f.read(8))
        assert version == VERSION, f"bad version {version}"
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            n = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(4 * n), dtype="<f4")
            out[name] = data.reshape(dims).copy()
    return out
