"""AOT lowering driver — the only entry point of the python layer.

`make artifacts` runs this once; it emits, per model/variant:
  - HLO **text** for every executable the rust coordinator needs
    (infer + train steps per freeze pattern),
  - the dense-model init checkpoint (binary, `ckpt.py` format),
  - `artifacts/manifest.json` describing every artifact's signature
    (ordered parameter names/shapes) plus each variant's decomposition
    config (layer kinds + ranks) so rust decomposes with identical ranks.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import ckpt
from .configs import MODELS, build_config, param_shapes
from .resnet import resnet_apply
from .train import init_params, make_infer, make_train_step, split_params
from .vit import vit_apply

APPLY = {"resnet_mini": resnet_apply, "vit_mini": vit_apply}

# (variant, freeze-patterns-to-lower). "orig" has no factors to freeze.
VARIANTS = {
    "orig": ("none",),
    "lrd": ("none", "a", "b"),
    "rankopt": ("none", "a", "b"),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def shapes_entry(names, shapes):
    return [{"name": n, "shape": list(shapes[n])} for n in names]


def lower_train(model, variant, pattern, out_dir, alpha, tile):
    spec_m = MODELS[model]
    cfg = build_config(model, variant, alpha=alpha, tile=tile)
    shapes = param_shapes(model, cfg)
    trainable, frozen = split_params(model, cfg, pattern)
    step = make_train_step(APPLY[model], cfg, trainable, frozen)

    b = spec_m["train_batch"]
    h, w, c = spec_m["image"]
    args = (
        [spec(shapes[n]) for n in trainable]
        + [spec(shapes[n]) for n in frozen]
        + [spec(shapes[n]) for n in trainable]  # momenta
        + [
            jax.ShapeDtypeStruct((b, h, w, c), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.float32),
        ]
    )
    name = f"{model}_{variant}_train_{pattern}"
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    lowered = jax.jit(step).lower(*args)
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return {
        "name": name,
        "path": os.path.basename(path),
        "model": model,
        "variant": variant,
        "kind": "train",
        "freeze": pattern,
        "batch": b,
        "trainable": shapes_entry(trainable, shapes),
        "frozen": shapes_entry(frozen, shapes),
        "data": {
            "x": [b, h, w, c],
            "y": [b],
        },
        "outputs": ["new_trainable...", "new_momenta...", "loss", "correct"],
    }


def lower_infer(model, variant, out_dir, alpha, tile):
    spec_m = MODELS[model]
    cfg = build_config(model, variant, alpha=alpha, tile=tile)
    shapes = param_shapes(model, cfg)
    names = list(shapes)
    infer = make_infer(APPLY[model], cfg, names)

    b = spec_m["infer_batch"]
    h, w, c = spec_m["image"]
    args = [spec(shapes[n]) for n in names] + [
        jax.ShapeDtypeStruct((b, h, w, c), jnp.float32)
    ]
    name = f"{model}_{variant}_infer"
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    lowered = jax.jit(infer).lower(*args)
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return {
        "name": name,
        "path": os.path.basename(path),
        "model": model,
        "variant": variant,
        "kind": "infer",
        "freeze": "none",
        "batch": b,
        "trainable": shapes_entry(names, shapes),
        "frozen": [],
        "data": {"x": [b, h, w, c]},
        "outputs": ["logits"],
    }


def lower_metrics_acc(out_dir):
    """The on-device metric-accumulation step of the pipelined trainer:
    ``acc' = acc + loss*e_loss + correct*e_correct`` over a resident
    ``[loss_sum, correct_sum]`` buffer. Model-independent (one artifact for
    the whole manifest); the rust runtime falls back to an identical
    XlaBuilder-built computation when this artifact is absent
    (``rust/src/runtime/builder.rs::metrics_accumulate_computation`` — the
    two must keep the same 5-input contract)."""

    def acc_step(acc, loss, correct, e_loss, e_correct):
        return acc + loss * e_loss + correct * e_correct

    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    args = [spec([2]), scalar, scalar, spec([2]), spec([2])]
    name = "metrics_acc"
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    lowered = jax.jit(acc_step).lower(*args)
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return {
        "name": name,
        "path": os.path.basename(path),
        "model": "",
        "variant": "",
        "kind": "metrics",
        "freeze": "none",
        "batch": 1,
        "trainable": [],
        "frozen": [],
        # data.x is the accumulator shape (the manifest schema requires x)
        "data": {"x": [2]},
        "outputs": ["acc"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; artifacts land beside it")
    ap.add_argument("--models", default="resnet_mini,vit_mini")
    ap.add_argument("--alpha", type=float, default=2.0)
    ap.add_argument("--tile", type=int, default=16,
                    help="rank-quantization tile for the rankopt variant")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)

    manifest = {
        "version": 1,
        "alpha": args.alpha,
        "tile": args.tile,
        "artifacts": [],
        "configs": {},
        "init_checkpoints": {},
    }

    entry = lower_metrics_acc(out_dir)
    manifest["artifacts"].append(entry)
    print(f"[aot] lowered {entry['name']}")

    for model in args.models.split(","):
        model = model.strip()
        # init checkpoint for the dense model (pretraining starts here)
        cfg_orig = build_config(model, "orig")
        params = init_params(model, cfg_orig, seed=args.seed)
        ck_path = os.path.join(out_dir, f"{model}_init.bin")
        ckpt.save(ck_path, params)
        manifest["init_checkpoints"][model] = os.path.basename(ck_path)
        print(f"[aot] wrote {ck_path} ({len(params)} tensors)")

        for variant, patterns in VARIANTS.items():
            cfg = build_config(model, variant, alpha=args.alpha, tile=args.tile)
            manifest["configs"][f"{model}_{variant}"] = cfg
            entry = lower_infer(model, variant, out_dir, args.alpha, args.tile)
            manifest["artifacts"].append(entry)
            print(f"[aot] lowered {entry['name']}")
            for pattern in patterns:
                entry = lower_train(model, variant, pattern, out_dir,
                                    args.alpha, args.tile)
                manifest["artifacts"].append(entry)
                print(f"[aot] lowered {entry['name']}")

    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {args.out} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    sys.exit(main())
