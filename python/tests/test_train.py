"""Train-step semantics: freeze splits (must mirror rust `freeze::
frozen_param_names`), SGD update math, gradient flow under freezing, and
the checkpoint binary format."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import ckpt
from compile.configs import build_config, param_shapes
from compile.resnet import resnet_apply
from compile.train import (
    MOMENTUM,
    WEIGHT_DECAY,
    frozen_names_for_pattern,
    init_params,
    lr_cosine,
    make_infer,
    make_train_step,
    split_params,
)


class TestFreezeSplits:
    def test_pattern_none_freezes_nothing(self):
        cfg = build_config("resnet_mini", "lrd")
        assert frozen_names_for_pattern(cfg, "none") == set()

    def test_patterns_partition_factors(self):
        # mirrors rust prop_coordinator::prop_patterns_partition_factors
        cfg = build_config("resnet_mini", "lrd")
        a = frozen_names_for_pattern(cfg, "a")
        b = frozen_names_for_pattern(cfg, "b")
        assert a and b and not (a & b)
        expected = set()
        for lname, lcfg in cfg.items():
            if lcfg["kind"] == "svd":
                expected |= {f"{lname}.a", f"{lname}.b"}
            elif lcfg["kind"] == "tucker":
                expected |= {f"{lname}.first", f"{lname}.core", f"{lname}.last"}
        assert a | b == expected

    def test_split_params_ordering_stable(self):
        cfg = build_config("vit_mini", "lrd")
        tr1, fz1 = split_params("vit_mini", cfg, "a")
        tr2, fz2 = split_params("vit_mini", cfg, "a")
        assert tr1 == tr2 and fz1 == fz2
        shapes = param_shapes("vit_mini", cfg)
        assert set(tr1) | set(fz1) == set(shapes)
        assert not set(tr1) & set(fz1)

    def test_orig_variant_has_no_frozen(self):
        cfg = build_config("resnet_mini", "orig")
        for pattern in ("a", "b"):
            _, fz = split_params("resnet_mini", cfg, pattern)
            assert fz == []


class TestTrainStepMath:
    def _setup(self, pattern="none"):
        cfg = build_config("resnet_mini", "lrd")
        p = init_params("resnet_mini", cfg, seed=3)
        tr, fz = split_params("resnet_mini", cfg, pattern)
        step = make_train_step(resnet_apply, cfg, tr, fz)
        x = jnp.asarray(np.random.RandomState(0).randn(4, 32, 32, 3), jnp.float32)
        y = jnp.asarray([0, 1, 2, 3], jnp.int32)
        args = (
            [p[n] for n in tr]
            + [p[n] for n in fz]
            + [jnp.zeros_like(p[n]) for n in tr]
            + [x, y, jnp.float32(0.01)]
        )
        return cfg, p, tr, fz, step, args

    def test_output_arity(self):
        _, _, tr, _, step, args = self._setup()
        out = step(*args)
        assert len(out) == 2 * len(tr) + 2

    def test_sgd_update_matches_manual(self):
        # new_w = w - lr * (momentum*m + g + wd*w); with m=0:
        # new_w = w - lr*(g + wd*w)  => verify on one parameter
        _, p, tr, fz, step, args = self._setup()
        x, y, lr = args[-3], args[-2], args[-1]

        def loss_fn(tr_tuple):
            cfg = build_config("resnet_mini", "lrd")
            full = dict(zip(tr, tr_tuple))
            full.update({n: p[n] for n in fz})
            from compile import layers as L

            return L.softmax_cross_entropy(resnet_apply(full, cfg, x), y)

        grads = jax.grad(loss_fn)(tuple(p[n] for n in tr))
        out = step(*args)
        i = tr.index("head.bias")
        manual = p[tr[i]] - lr * (grads[i] + WEIGHT_DECAY * p[tr[i]])
        np.testing.assert_allclose(out[i], manual, rtol=1e-5, atol=1e-6)
        # momentum output equals g + wd*w on the first step
        np.testing.assert_allclose(
            out[len(tr) + i], grads[i] + WEIGHT_DECAY * p[tr[i]], rtol=1e-5, atol=1e-6
        )

    def test_momentum_accumulates(self):
        _, _, tr, fz, step, args = self._setup()
        assert fz == []  # pattern "none"
        out1 = step(*args)
        n = len(tr)
        new_tr = list(out1[:n])
        new_mom = list(out1[n : 2 * n])
        x, y, lr = args[-3], args[-2], args[-1]
        out2 = step(*(new_tr + new_mom + [x, y, lr]))
        m2 = out2[n]
        # second-step momentum = MOMENTUM*m1 + g2 + wd*w: differs from the
        # pure decay term because fresh gradients are added
        assert float(jnp.abs(m2 - MOMENTUM * new_mom[0]).max()) > 0.0

    def test_loss_decreases_over_steps(self):
        # overfit a single fixed batch at a conservative LR: the loss trend
        # must go down (random-init LRD nets oscillate at larger LRs)
        _, _, tr, _, step, args = self._setup()
        n = len(tr)
        cur = [a if i != len(args) - 1 else jnp.float32(2e-4) for i, a in enumerate(args)]
        losses = []
        for _ in range(8):
            out = step(*cur)
            losses.append(float(out[-2]))
            cur = list(out[:n]) + list(out[n : 2 * n]) + cur[-3:]
        assert min(losses[-4:]) < losses[0] * 0.7, losses

    def test_frozen_grads_never_computed(self):
        # pattern a: the frozen factors are plain inputs; jacobian wrt them
        # is never requested. Structural check: output count shrinks.
        cfg = build_config("resnet_mini", "lrd")
        tr_a, fz_a = split_params("resnet_mini", cfg, "a")
        tr_n, fz_n = split_params("resnet_mini", cfg, "none")
        assert len(tr_a) < len(tr_n)
        assert len(fz_a) > 0 and len(fz_n) == 0

    def test_infer_matches_apply(self):
        cfg = build_config("resnet_mini", "lrd")
        p = init_params("resnet_mini", cfg, seed=4)
        names = list(param_shapes("resnet_mini", cfg))
        infer = make_infer(resnet_apply, cfg, names)
        x = jnp.asarray(np.random.RandomState(1).randn(2, 32, 32, 3), jnp.float32)
        got = infer(*[p[n] for n in names], x)
        want = resnet_apply(p, cfg, x)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestSchedulesAndCkpt:
    def test_lr_cosine_endpoints(self):
        assert lr_cosine(1.0, 0, 100) == pytest.approx(1.0)
        assert lr_cosine(1.0, 100, 100) == pytest.approx(0.0, abs=1e-7)
        assert lr_cosine(1.0, 50, 100) == pytest.approx(0.5)

    @settings(max_examples=20, deadline=None)
    @given(step=st.integers(0, 200), total=st.integers(1, 200))
    def test_lr_cosine_bounded_monotone(self, step, total):
        lr = lr_cosine(0.1, step, total)
        assert 0.0 <= lr <= 0.1
        if step < total:
            assert lr_cosine(0.1, step + 1, total) <= lr + 1e-12

    def test_ckpt_roundtrip(self, tmp_path):
        params = {
            "w": np.random.RandomState(0).randn(3, 4).astype(np.float32),
            "nested.name.bias": np.zeros(7, np.float32),
        }
        path = str(tmp_path / "t.bin")
        ckpt.save(path, params)
        back = ckpt.load(path)
        assert set(back) == set(params)
        for k in params:
            np.testing.assert_array_equal(back[k], params[k])

    def test_ckpt_layout_matches_rust_reader(self, tmp_path):
        # byte-level pin of the shared format (rust has the mirror test)
        path = str(tmp_path / "pin.bin")
        ckpt.save(path, {"t": np.asarray([[1.5, -2.0]], np.float32)})
        raw = open(path, "rb").read()
        assert raw[:4] == b"LRTA"
        assert int.from_bytes(raw[4:8], "little") == 1  # version
        assert int.from_bytes(raw[8:12], "little") == 1  # count
        assert int.from_bytes(raw[12:16], "little") == 1  # name len
        assert raw[16:17] == b"t"
        assert int.from_bytes(raw[17:21], "little") == 2  # ndim
