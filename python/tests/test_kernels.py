"""L1 kernel correctness: Pallas lowrank kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes; fixed cases pin the MXU-aligned paths and the
custom-VJP backward rule.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lowrank import (
    _pick_block_m,
    lowrank_matmul,
    lowrank_mxu_flops,
    lowrank_vmem_bytes,
)
from compile.kernels.ref import lowrank_matmul_ref


def rand(shape, seed=0, scale=1.0):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape) * scale, jnp.float32)


class TestLowrankForward:
    @pytest.mark.parametrize(
        "m,c,r,s",
        [
            (128, 64, 16, 64),   # MXU-aligned
            (256, 128, 32, 128),
            (64, 48, 17, 128),   # odd rank (pre-quantization LRD rank)
            (96, 40, 8, 24),
            (8, 3, 1, 5),        # degenerate tiny
            (1, 7, 2, 3),        # single row
        ],
    )
    def test_matches_oracle(self, m, c, r, s):
        x, a, b = rand((m, c), 1), rand((c, r), 2), rand((r, s), 3)
        got = lowrank_matmul(x, a, b)
        want = lowrank_matmul_ref(x, a, b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_block_m_smaller_than_m(self):
        x, a, b = rand((512, 32), 4), rand((32, 8), 5), rand((8, 16), 6)
        got = lowrank_matmul(x, a, b, block_m=64)
        np.testing.assert_allclose(got, lowrank_matmul_ref(x, a, b), rtol=1e-5, atol=1e-5)

    def test_zero_inputs(self):
        x = jnp.zeros((32, 16), jnp.float32)
        a, b = rand((16, 4), 7), rand((4, 8), 8)
        assert jnp.all(lowrank_matmul(x, a, b) == 0.0)

    def test_identity_factors(self):
        x = rand((16, 8), 9)
        eye = jnp.eye(8, dtype=jnp.float32)
        np.testing.assert_allclose(lowrank_matmul(x, eye, eye), x, rtol=1e-6, atol=1e-6)

    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(1, 96),
        c=st.integers(1, 48),
        r=st.integers(1, 24),
        s=st.integers(1, 48),
        seed=st.integers(0, 2**20),
    )
    def test_hypothesis_shape_sweep(self, m, c, r, s, seed):
        x, a, b = rand((m, c), seed), rand((c, r), seed + 1), rand((r, s), seed + 2)
        got = lowrank_matmul(x, a, b)
        want = lowrank_matmul_ref(x, a, b)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(max_examples=15, deadline=None)
    @given(scale=st.sampled_from([1e-3, 1.0, 1e3]), seed=st.integers(0, 1000))
    def test_hypothesis_scale_sweep(self, scale, seed):
        x = rand((32, 16), seed, scale)
        a = rand((16, 4), seed + 1, scale)
        b = rand((4, 8), seed + 2, scale)
        got = lowrank_matmul(x, a, b)
        want = lowrank_matmul_ref(x, a, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale**3)


class TestLowrankBackward:
    def test_grads_match_oracle(self):
        x, a, b = rand((64, 32), 10), rand((32, 8), 11), rand((8, 16), 12)

        def loss_kernel(x, a, b):
            return (lowrank_matmul(x, a, b) ** 2).sum()

        def loss_ref(x, a, b):
            return (lowrank_matmul_ref(x, a, b) ** 2).sum()

        gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, a, b)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, a, b)
        for got, want, name in zip(gk, gr, "xab"):
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4, err_msg=name)

    def test_grad_under_jit(self):
        x, a, b = rand((32, 16), 13), rand((16, 4), 14), rand((4, 8), 15)
        f = jax.jit(jax.grad(lambda a: lowrank_matmul(x, a, b).sum()))
        g = f(a)
        g_ref = jax.grad(lambda a: lowrank_matmul_ref(x, a, b).sum())(a)
        np.testing.assert_allclose(g, g_ref, rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([8, 32, 64]),
        c=st.integers(2, 32),
        r=st.integers(1, 12),
        s=st.integers(2, 32),
        seed=st.integers(0, 1000),
    )
    def test_hypothesis_vjp_sweep(self, m, c, r, s, seed):
        x, a, b = rand((m, c), seed), rand((c, r), seed + 1), rand((r, s), seed + 2)
        g = rand((m, s), seed + 3)
        _, vjp_k = jax.vjp(lowrank_matmul, x, a, b)
        _, vjp_r = jax.vjp(lowrank_matmul_ref, x, a, b)
        for got, want in zip(vjp_k(g), vjp_r(g)):
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


class TestBlockPicker:
    def test_divides(self):
        for m in [1, 7, 64, 96, 128, 300, 1024]:
            bm = _pick_block_m(m, 128)
            assert m % bm == 0, (m, bm)
            assert bm <= max(m, 128)

    def test_prefers_mxu_alignment(self):
        assert _pick_block_m(1024, 128) == 128
        assert _pick_block_m(256, 128) == 128
        assert _pick_block_m(96, 128) == 96  # m < bm -> whole block

    def test_respects_requested_cap(self):
        assert _pick_block_m(1024, 64) == 64


class TestTpuEstimates:
    def test_vmem_bytes(self):
        # bm*C + C*r + r*S + bm*r + bm*S floats, 4 bytes each
        assert lowrank_vmem_bytes(128, 64, 16, 64) == 4 * (
            128 * 64 + 64 * 16 + 16 * 64 + 128 * 16 + 128 * 64
        )

    def test_vmem_fits_16mb_for_model_shapes(self):
        # every decomposed layer in the zoo must fit VMEM comfortably
        for c, r, s in [(128, 32, 128), (512, 309, 512), (512, 256, 512)]:
            assert lowrank_vmem_bytes(128, c, r, s) < 16 * 2**20

    def test_flops(self):
        assert lowrank_mxu_flops(128, 64, 16, 32) == 2 * 128 * 64 * 16 + 2 * 128 * 16 * 32
