"""L2 model tests: shapes, decomposed-vs-dense consistency, layer oracles,
and GroupNorm/LayerNorm refs."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers as L
from compile.configs import build_config, param_shapes
from compile.kernels import ref as R
from compile.resnet import resnet_apply
from compile.train import init_params
from compile.vit import vit_apply

APPLY = {"resnet_mini": resnet_apply, "vit_mini": vit_apply}


def batch(n=4, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randn(n, 32, 32, 3), jnp.float32)


class TestForwardShapes:
    @pytest.mark.parametrize("model", ["resnet_mini", "vit_mini"])
    @pytest.mark.parametrize("variant", ["orig", "lrd", "rankopt"])
    def test_logits_shape(self, model, variant):
        cfg = build_config(model, variant)
        p = init_params(model, cfg, seed=1)
        logits = APPLY[model](p, cfg, batch())
        assert logits.shape == (4, 10)
        assert bool(jnp.isfinite(logits).all())

    @pytest.mark.parametrize("model", ["resnet_mini", "vit_mini"])
    def test_batch_independence(self, model):
        # row i of logits depends only on image i
        cfg = build_config(model, "lrd")
        p = init_params(model, cfg, seed=2)
        x = batch(4, seed=3)
        full = APPLY[model](p, cfg, x)
        solo = APPLY[model](p, cfg, x[1:2].repeat(4, 0))[0]
        np.testing.assert_allclose(full[1], solo, rtol=2e-4, atol=2e-4)


class TestDecomposedConsistency:
    """Initialize a decomposed layer with *exact* factorizations of a dense
    layer and verify the decomposed forward equals the dense forward."""

    def test_svd_linear_exact_factors(self):
        rng = np.random.RandomState(4)
        w = jnp.asarray(rng.randn(32, 24), jnp.float32)
        u, s, vt = np.linalg.svd(np.asarray(w), full_matrices=False)
        a = jnp.asarray(u * np.sqrt(s), jnp.float32)
        b = jnp.asarray((vt.T * np.sqrt(s)).T, jnp.float32)
        x = jnp.asarray(rng.randn(16, 32), jnp.float32)
        p = {"l.a": a, "l.b": b, "l.bias": jnp.zeros(24)}
        pd = {"l.w": w, "l.bias": jnp.zeros(24)}
        got = L.svd_linear(p, "l", x)
        want = L.dense_linear(pd, "l", x)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_tucker_conv_full_rank_equals_dense(self):
        rng = np.random.RandomState(5)
        c, s, k = 8, 12, 3
        w = rng.randn(k, k, c, s).astype(np.float32)  # HWIO
        # identity factors + dense core == the dense conv
        p = {
            "c.first": jnp.eye(c, dtype=jnp.float32),
            "c.core": jnp.asarray(w),
            "c.last": jnp.eye(s, dtype=jnp.float32),
            "c.bias": jnp.zeros(s),
        }
        pd = {"c.w": jnp.asarray(w), "c.bias": jnp.zeros(s)}
        x = jnp.asarray(rng.randn(2, 8, 8, c), jnp.float32)
        got = L.tucker_conv(p, "c", x)
        want = L.conv2d(pd, "c", x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_tucker_conv_stride_matches_ref(self):
        rng = np.random.RandomState(6)
        p = {
            "c.first": jnp.asarray(rng.randn(8, 4), jnp.float32),
            "c.core": jnp.asarray(rng.randn(3, 3, 4, 5), jnp.float32),
            "c.last": jnp.asarray(rng.randn(5, 12), jnp.float32),
            "c.bias": jnp.zeros(12),
        }
        x = jnp.asarray(rng.randn(2, 8, 8, 8), jnp.float32)
        got = L.tucker_conv(p, "c", x, stride=2)
        want = R.tucker_conv_ref(x, p["c.first"], p["c.core"], p["c.last"], stride=2)
        np.testing.assert_allclose(got, want + 0.0, rtol=1e-4, atol=1e-4)
        assert got.shape == (2, 4, 4, 12)


class TestNormOracles:
    @settings(max_examples=20, deadline=None)
    @given(c=st.sampled_from([8, 16, 32]), seed=st.integers(0, 500))
    def test_group_norm_matches_ref(self, c, seed):
        x = jnp.asarray(np.random.RandomState(seed).randn(2, 4, 4, c), jnp.float32)
        p = {"n.gamma": jnp.ones(c), "n.beta": jnp.zeros(c)}
        got = L.group_norm(p, "n", x)
        want = R.group_norm_ref(x, p["n.gamma"], p["n.beta"])
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_group_norm_normalizes(self):
        x = jnp.asarray(np.random.RandomState(7).randn(4, 8, 8, 32) * 10 + 3, jnp.float32)
        p = {"n.gamma": jnp.ones(32), "n.beta": jnp.zeros(32)}
        y = L.group_norm(p, "n", x)
        assert abs(float(y.mean())) < 0.05
        assert abs(float(y.std()) - 1.0) < 0.05

    def test_layer_norm_matches_ref(self):
        x = jnp.asarray(np.random.RandomState(8).randn(6, 16), jnp.float32)
        p = {"n.gamma": jnp.ones(16), "n.beta": jnp.zeros(16)}
        got = L.layer_norm(p, "n", x)
        want = R.layer_norm_ref(x, p["n.gamma"], p["n.beta"])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestLossOracles:
    def test_cross_entropy_matches_ref(self):
        logits = jnp.asarray(np.random.RandomState(9).randn(12, 10), jnp.float32)
        y = jnp.asarray(np.random.RandomState(10).randint(0, 10, 12), jnp.int32)
        got = L.softmax_cross_entropy(logits, y)
        want = R.softmax_cross_entropy_ref(logits, y)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_cross_entropy_uniform(self):
        logits = jnp.zeros((4, 10), jnp.float32)
        y = jnp.zeros((4,), jnp.int32)
        np.testing.assert_allclose(
            L.softmax_cross_entropy(logits, y), np.log(10.0), rtol=1e-5
        )

    def test_num_correct(self):
        logits = jnp.asarray([[1.0, 0.0], [0.0, 1.0], [3.0, -1.0]])
        y = jnp.asarray([0, 0, 0], jnp.int32)
        assert float(L.num_correct(logits, y)) == 2.0
