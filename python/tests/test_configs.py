"""Config / rank-formula tests. These pin the python mirror of the rank
math to the same values the rust `lrd` module asserts (e.g. the paper's
[512,512,3,3] @ 2x -> 309 example), keeping the two implementations honest.
"""

import math
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import (
    MODELS,
    build_config,
    decomposed_params,
    model_layers,
    param_shapes,
    snap_rank,
    svd_rank,
    svd_rmin,
    total_params,
    tucker_rank_eq5,
    tucker_rmin_eq6,
)


class TestRankFormulas:
    def test_paper_example_512(self):
        assert tucker_rank_eq5(512, 512, 3, 2.0) in (308, 309, 310)

    def test_svd_rank_512(self):
        assert svd_rank(512, 512, 2.0) == 128

    def test_rmin_below_nominal(self):
        assert tucker_rmin_eq6(512, 512, 3, 2.0) < tucker_rank_eq5(512, 512, 3, 2.0)
        assert svd_rmin(256, 256, 2.0) < svd_rank(256, 256, 2.0)

    @settings(max_examples=60, deadline=None)
    @given(
        c=st.integers(8, 512),
        s=st.integers(8, 512),
        k=st.sampled_from([1, 3, 5]),
        alpha=st.sampled_from([1.5, 2.0, 3.0, 4.0]),
    )
    def test_eq5_hits_compression(self, c, s, k, alpha):
        if k == 1:
            r = svd_rank(c, s, alpha)
            dec = decomposed_params(c, s, 1, r, r)
        else:
            r = tucker_rank_eq5(c, s, k, alpha)
            dec = decomposed_params(c, s, k, r, r)
        dense = c * s * k * k
        # floor() => at least alpha (tiny layers can overshoot hugely)
        assert dense / dec >= alpha * 0.95 or r == 1

    @settings(max_examples=40, deadline=None)
    @given(r=st.integers(1, 512), rmin=st.integers(1, 512), tile=st.sampled_from([8, 16, 32, 64, 128]))
    def test_snap_rank_invariants(self, r, rmin, tile):
        rmin = min(rmin, r)
        snapped = snap_rank(r, rmin, tile)
        assert snapped >= 1
        # snapped is either a tile multiple or the original rank
        assert snapped % tile == 0 or snapped == r
        # never far above nominal
        assert snapped <= r + tile // 2


class TestConfigs:
    @pytest.mark.parametrize("model", list(MODELS))
    def test_orig_is_all_dense(self, model):
        cfg = build_config(model, "orig")
        assert all(v["kind"] == "dense" for v in cfg.values())

    @pytest.mark.parametrize("model", list(MODELS))
    def test_lrd_compresses_about_2x_on_decomposed_layers(self, model):
        cfg_o = build_config(model, "orig")
        cfg_l = build_config(model, "lrd", alpha=2.0)
        dense = total_params(param_shapes(model, cfg_o))
        lrd = total_params(param_shapes(model, cfg_l))
        assert lrd < dense
        # decomposed layers hit ~2x; aux params + dense-kept layers dilute
        assert dense / lrd > 1.3

    def test_rankopt_ranks_are_tile_multiples(self):
        cfg = build_config("resnet_mini", "rankopt", tile=16)
        for name, lcfg in cfg.items():
            if lcfg["kind"] == "tucker":
                assert lcfg["r1"] % 16 == 0 or lcfg["r1"] >= lcfg["r_min"], name
            if lcfg["kind"] == "svd":
                assert lcfg["rank"] % 16 == 0 or lcfg["rank"] >= lcfg["r_min"], name

    def test_vit_attention_stays_dense(self):
        cfg = build_config("vit_mini", "lrd")
        for name, lcfg in cfg.items():
            if "attn" in name:
                assert lcfg["kind"] == "dense", name

    def test_resnet_stem_rank_clamped_to_channels(self):
        # Eq. 5 on the 3-channel stem exceeds the mode-rank bound; the
        # config must clamp r1 <= C so factor shapes are well-posed.
        cfg = build_config("resnet_mini", "lrd")
        assert cfg["stem"]["kind"] == "tucker"
        assert cfg["stem"]["r1"] <= 3

    def test_all_ranks_within_mode_bounds(self):
        for model in MODELS:
            for variant in ("lrd", "rankopt"):
                cfg = build_config(model, variant)
                for name, ltype, meta in model_layers(model):
                    lcfg = cfg[name]
                    if lcfg["kind"] == "svd":
                        assert lcfg["rank"] <= min(meta["c"], meta["s"]), name
                    elif lcfg["kind"] == "tucker":
                        assert lcfg["r1"] <= meta["c"], name
                        assert lcfg["r2"] <= meta["s"], name

    @pytest.mark.parametrize("model", list(MODELS))
    def test_param_shapes_deterministic(self, model):
        cfg = build_config(model, "lrd")
        s1 = list(param_shapes(model, cfg).items())
        s2 = list(param_shapes(model, cfg).items())
        assert s1 == s2

    @pytest.mark.parametrize("model", list(MODELS))
    def test_layer_inventory_shapes_positive(self, model):
        for name, ltype, meta in model_layers(model):
            assert meta["c"] > 0 and meta["s"] > 0
            assert ltype in ("conv", "conv1x1", "linear")

    def test_total_params_matches_manual(self):
        shapes = {"a": (2, 3), "b": (4,)}
        assert total_params(shapes) == 10
